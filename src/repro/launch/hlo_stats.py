"""Roofline accounting from SPMD-partitioned HLO text.

``compiled.cost_analysis()`` visits while-loop bodies ONCE, so for a
scan-over-layers program it underreports FLOPs/bytes by ~n_layers, and it
reports no collective traffic at all.  This module re-derives all three
roofline numerators from ``compiled.as_text()`` with loop weighting:

  * computations are parsed into a call graph; ``while`` ops carry
    ``backend_config={"known_trip_count":{"n":...}}`` (fallback: the
    comparison constant in the condition computation), and a DFS from
    ENTRY multiplies nested trip counts;
  * FLOPs: 2 * prod(result dims) * prod(lhs contracting dims) per ``dot``
    (elementwise FLOPs are ignored — dots dominate every assigned arch);
  * bytes: fusion-boundary accounting — result + operand bytes for every
    materialized op in visited computations (fusion-internal ops are
    invisible because ``calls=`` edges are not followed), mirroring
    HloCostAnalysis bytes_accessed semantics;
  * collectives: per-kind counts/bytes with ring-algorithm ICI factors:
        all-gather          out * (n-1)/n
        reduce-scatter      out * (n-1)
        all-reduce          2 * shard * (n-1)/n
        all-to-all          bytes * (n-1)/n
        collective-permute  bytes
    n parsed from replica_groups ([groups,size]<=... iota or explicit).
"""
from __future__ import annotations

import re
from collections import defaultdict
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

COLLECTIVE_KINDS = ("all-gather", "all-reduce", "reduce-scatter",
                    "all-to-all", "collective-permute")

_SHAPE_RE = re.compile(r"\b(\w+)\[([\d,]*)\]")
_GROUPS_ARR_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_CONST_RE = re.compile(r"constant\((\d+)\)")
_COMP_START_RE = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s+\(.*\{\s*$")
_OP_RE = re.compile(
    r"^(?:ROOT\s+)?%?([\w.\-]+)\s+=\s+(.*?)\s+([\w\-]+)\((.*)$")
_WHILE_ATTR_RE = re.compile(
    r"condition=%?([\w.\-]+).*?body=%?([\w.\-]+)")
_CALLEE_RE = re.compile(r"to_apply=%?([\w.\-]+)")
_FUSION_CALLS_RE = re.compile(r"calls=%?([\w.\-]+)")
_BRANCHES_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")
_DIMS_ATTR = lambda name, s: re.search(name + r"=\{([\d,]*)\}", s)  # noqa

_BYTES_SKIP = {"parameter", "constant", "get-tuple-element", "tuple",
               "bitcast", "while", "call", "conditional", "after-all",
               "partition-id", "replica-id"}


def _parse_shape(type_str: str) -> Tuple[int, Optional[List[int]]]:
    """-> (total bytes, dims of the first array shape or None)."""
    total = 0
    first_dims: Optional[List[int]] = None
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims_s = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        dims = [int(d) for d in dims_s.split(",") if d]
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES[dt]
        if first_dims is None:
            first_dims = dims
    return total, first_dims


def _group_size(line: str, default: int = 1) -> int:
    m = _GROUPS_ARR_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_RE.search(line)
    if m:
        return len([x for x in m.group(1).split(",") if x.strip()])
    return default


def _ici_factor(kind: str, n: int) -> float:
    if n <= 1:
        return 0.0
    if kind == "all-gather":
        return (n - 1) / n
    if kind == "all-reduce":
        return 2 * (n - 1) / n
    if kind == "reduce-scatter":
        return float(n - 1)
    if kind == "all-to-all":
        return (n - 1) / n
    return 1.0


class _Op:
    __slots__ = ("kind", "result_bytes", "result_dims", "operands",
                 "attrs_str", "line")

    def __init__(self, kind, result_bytes, result_dims, operands,
                 attrs_str, line):
        self.kind = kind
        self.result_bytes = result_bytes
        self.result_dims = result_dims
        self.operands = operands
        self.attrs_str = attrs_str
        self.line = line


class _Comp:
    def __init__(self) -> None:
        self.ops: List[_Op] = []
        self.whiles: List[Tuple[str, str, Optional[int]]] = []
        self.calls: List[str] = []        # call/conditional targets
        self.fusion_calls: List[str] = [] # fusion bodies (FLOPs only)
        self.max_const = 0
        self.param_index: Dict[str, int] = {}   # %name -> parameter(N)
        # parameter index -> bytes actually READ when the body only
        # slices the parameter (scan-over-layers weight fetch pattern)
        self.sliced_param_bytes: Dict[int, float] = {}


class HloStats:
    def __init__(self, hlo_text: str):
        self.comps: Dict[str, _Comp] = {}
        self.entry: Optional[str] = None
        self.symbols: Dict[str, Tuple[int, Optional[List[int]]]] = {}
        self._parse(hlo_text)
        self._accumulate()

    # -- parsing ---------------------------------------------------------------
    def _parse(self, text: str) -> None:
        current: Optional[str] = None
        for raw in text.splitlines():
            line = raw.rstrip()
            stripped = line.strip()
            if current is None:
                m = _COMP_START_RE.match(line)
                if m:
                    current = m.group(2)
                    self.comps[current] = _Comp()
                    if m.group(1):
                        self.entry = current
                continue
            if stripped == "}":
                current = None
                continue
            comp = self.comps[current]
            mo = _OP_RE.match(stripped)
            if not mo:
                continue
            name, type_str, kind, rest = mo.groups()
            rbytes, rdims = _parse_shape(type_str)
            self.symbols[name] = (rbytes, rdims)
            if kind == "parameter":
                mp = re.match(r"^(\d+)", rest)
                if mp:
                    comp.param_index[name] = int(mp.group(1))
            if kind in ("dynamic-slice", "slice", "gather"):
                # if the sliced operand is a fusion parameter, the body
                # reads only the slice — record the cap for the caller
                args_seg0 = rest.split("), ")[0]
                ops0 = _OPERAND_RE.findall(args_seg0)
                if ops0 and ops0[0] in comp.param_index:
                    idx = comp.param_index[ops0[0]]
                    prev = comp.sliced_param_bytes.get(idx, 0.0)
                    comp.sliced_param_bytes[idx] = prev + rbytes
            for c in _CONST_RE.finditer(stripped):
                comp.max_const = max(comp.max_const, int(c.group(1)))
            if kind == "while":
                mw = _WHILE_ATTR_RE.search(rest)
                trip = None
                mt = _TRIP_RE.search(rest)
                if mt:
                    trip = int(mt.group(1))
                if mw:
                    comp.whiles.append((mw.group(1), mw.group(2), trip))
                continue
            if kind in ("call", "conditional"):
                mc = _CALLEE_RE.search(rest)
                if mc:
                    comp.calls.append(mc.group(1))
                mb = _BRANCHES_RE.search(rest)
                if mb:
                    comp.calls.extend(
                        x.strip().lstrip("%") for x in
                        mb.group(1).split(","))
                continue
            if kind == "fusion":
                mf = _FUSION_CALLS_RE.search(rest)
                if mf:
                    comp.fusion_calls.append(mf.group(1))
                # fall through: the fusion op itself is byte-counted
            # operand names appear before the first '),' boundary; taking
            # all %refs in the args segment is fine (attrs use raw ints)
            args_seg = rest.split("), ")[0]
            operands = _OPERAND_RE.findall(args_seg)
            comp.ops.append(_Op(kind, rbytes, rdims, operands, rest,
                                stripped))

    # -- weighted accumulation ---------------------------------------------------
    def _trip_count(self, cond: str, hint: Optional[int]) -> int:
        if hint:
            return hint
        c = self.comps.get(cond)
        return max(c.max_const, 1) if c else 1

    def _comp_flops(self, name: str, depth: int = 0) -> float:
        """dot FLOPs of one computation INCLUDING nested fusion bodies
        (per single execution; memoized)."""
        memo = self._flops_memo
        if name in memo:
            return memo[name]
        comp = self.comps.get(name)
        if comp is None or depth > 32:
            return 0.0
        total = sum(self._dot_flops(op) for op in comp.ops
                    if op.kind == "dot")
        for callee in comp.fusion_calls:
            total += self._comp_flops(callee, depth + 1)
        memo[name] = total
        return total

    def _op_bytes(self, op: "_Op") -> float:
        """HBM-traffic model per op, at TPU fusion granularity: only ops
        that materialize data count; elementwise chains are assumed fused
        into their consumers (as the TPU backend does)."""
        kind = op.kind.replace("-start", "")
        res = op.result_bytes

        def operands_bytes():
            return sum(self.symbols.get(o, (0, None))[0]
                       for o in op.operands)

        if kind == "fusion":
            total = float(res)
            # operands that the body only SLICES are read at slice size
            caps: Dict[int, float] = {}
            for callee in _FUSION_CALLS_RE.findall(op.attrs_str):
                body = self.comps.get(callee)
                if body:
                    caps.update(body.sliced_param_bytes)
            for i, o in enumerate(op.operands):
                b = self.symbols.get(o, (0, None))[0]
                if i in caps:
                    b = min(b, caps[i])
                total += b
            return total
        if kind in ("dot", "convolution", "reduce",
                    "reduce-window", "sort", "custom-call"):
            return res + operands_bytes()
        if kind in ("dynamic-slice", "gather"):
            return 2.0 * res                       # read slice + write
        if kind == "dynamic-update-slice":
            # update tensor read+written; result aliases the operand
            upd = (self.symbols.get(op.operands[1], (0, None))[0]
                   if len(op.operands) > 1 else res)
            return 2.0 * upd
        if kind == "scatter":
            upd = (self.symbols.get(op.operands[2], (0, None))[0]
                   if len(op.operands) > 2 else res)
            return 2.0 * upd
        if kind in ("copy", "transpose", "reshape", "concatenate", "pad",
                    "slice", "reverse", "copy-start"):
            return 2.0 * res
        if kind in ("iota", "rng", "rng-bit-generator", "broadcast"):
            return res
        if kind in COLLECTIVE_KINDS:
            return 2.0 * res                       # HBM side of the wire
        return 0.0                                 # assumed fused away

    def _accumulate(self) -> None:
        self.flops = 0.0
        self.bytes = 0.0
        self._flops_memo: Dict[str, float] = {}
        self.collectives: Dict[str, Dict[str, float]] = defaultdict(
            lambda: {"count": 0.0, "bytes": 0.0, "ici_bytes": 0.0})
        self.top_collectives: List[Dict] = []   # per-op attribution
        # computations reachable as fusion bodies must not be double
        # counted when visiting: visit() walks only control-flow edges
        fusion_bodies = set()
        for comp in self.comps.values():
            fusion_bodies.update(comp.fusion_calls)

        def visit(name: str, weight: float, depth: int = 0) -> None:
            comp = self.comps.get(name)
            if comp is None or depth > 32:
                return
            for op in comp.ops:
                base = op.kind.replace("-start", "")
                if base in COLLECTIVE_KINDS and not op.kind.endswith(
                        "-done"):
                    n = _group_size(op.line)
                    st = self.collectives[base]
                    st["count"] += weight
                    st["bytes"] += op.result_bytes * weight
                    ici = op.result_bytes * _ici_factor(base, n) * weight
                    st["ici_bytes"] += ici
                    mm = re.search(r'op_name="([^"]*)"', op.line)
                    dm = re.match(r"(\w+)\[", op.line.split("= ", 1)[-1])
                    self.top_collectives.append({
                        "kind": base, "ici_bytes": ici,
                        "bytes": op.result_bytes, "weight": weight,
                        "dtype": dm.group(1) if dm else "?",
                        "group": n,
                        "op_name": mm.group(1) if mm else "?"})
                if op.kind == "dot":
                    self.flops += self._dot_flops(op) * weight
                elif op.kind == "fusion":
                    for callee in _FUSION_CALLS_RE.findall(op.attrs_str):
                        self.flops += self._comp_flops(callee) * weight
                if not op.kind.endswith("-done"):
                    self.bytes += self._op_bytes(op) * weight
            for cond, body, trip in comp.whiles:
                t = self._trip_count(cond, trip)
                visit(body, weight * t, depth + 1)
            for callee in comp.calls:
                visit(callee, weight, depth + 1)

        if self.entry is None and self.comps:
            self.entry = next(iter(self.comps))
        if self.entry:
            visit(self.entry, 1.0)
        self.collectives = dict(self.collectives)
        self.top_collectives.sort(key=lambda d: -d["ici_bytes"])
        self.top_collectives = self.top_collectives[:24]

    def _dot_flops(self, op: _Op) -> float:
        if not op.result_dims or not op.operands:
            return 0.0
        out = 1
        for d in op.result_dims:
            out *= d
        lhs = self.symbols.get(op.operands[0], (0, None))[1]
        m = _DIMS_ATTR("lhs_contracting_dims", op.attrs_str)
        contract = 1
        if lhs and m:
            for idx in m.group(1).split(","):
                if idx:
                    i = int(idx)
                    if i < len(lhs):
                        contract *= lhs[i]
        return 2.0 * out * contract

    @property
    def ici_bytes(self) -> float:
        return sum(s["ici_bytes"] for s in self.collectives.values())


def collective_stats(hlo_text: str) -> Dict[str, Dict[str, float]]:
    return HloStats(hlo_text).collectives


def total_ici_bytes(stats: Dict[str, Dict[str, float]]) -> float:
    return sum(s["ici_bytes"] for s in stats.values())
