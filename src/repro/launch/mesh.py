"""Production mesh construction.

``make_production_mesh`` is a FUNCTION (not a module-level constant) so
importing this module never touches jax device state — the dry-run
launcher must set XLA_FLAGS before anything initializes XLA.

Topology: TPU v5e pods of 16x16 = 256 chips.  Single-pod meshes are
("data", "model") = (16, 16); the multi-pod mesh prepends a "pod" axis:
(2, 16, 16) = 512 chips.  The pod axis carries pure data parallelism
(gradient all-reduce only — the slowest links get the most compressible
collective; see repro.distributed.compression), "data" carries DP+FSDP,
and "model" carries TP/EP/SP.
"""
from __future__ import annotations

from typing import Optional

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1):
    """Small mesh over whatever devices exist (CPU tests)."""
    return jax.make_mesh((data, model), ("data", "model"))
