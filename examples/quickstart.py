"""Quickstart: run the full MultiScope workflow on one synthetic dataset.

    PYTHONPATH=src python examples/quickstart.py

Trains the detector/proxy/tracker stack, selects θ_best, runs the greedy
tuner, and prints the speed-accuracy curve — Figure 1's workflow end to
end in a few minutes on CPU.
"""
import sys

sys.path.insert(0, "src")

from repro.configs.multiscope import MULTISCOPE_PIPELINE  # noqa: E402
from repro.core import tuner as tuner_mod  # noqa: E402
from repro.core.executor import run_clips  # noqa: E402
from repro.core.metrics import clip_count_accuracy  # noqa: E402
from repro.data.video_synth import make_split  # noqa: E402


def main() -> None:
    cfg = MULTISCOPE_PIPELINE.reduced()
    train = make_split("caldot1", "train", 4)
    val = make_split("caldot1", "val", 3)
    test = make_split("caldot1", "test", 3)

    print("== setup (detector / θ_best / proxies / windows / tracker) ==")
    system = tuner_mod.setup(cfg, train, val, detector_steps=250,
                             tracker_steps=800)

    print("\n== greedy joint tuning (§3.5) ==")
    curve = tuner_mod.tune(system, val)

    print("\n== the speed-accuracy curve, applied to the TEST split ==")
    # the streaming executor runs the whole split: decode prefetch is on
    # by default, and clip i+1's decode overlaps clip i's compute
    for pt in curve:
        results, secs = run_clips(system.bank, pt.params, test)
        accs = [clip_count_accuracy(r.tracks, clip)
                for r, clip in zip(results, test)]
        acc = sum(accs) / len(accs)
        print(f"  [{pt.module:10s}] test_acc={acc:.3f} "
              f"test_t={secs:6.2f}s  {pt.params.describe()}")


if __name__ == "__main__":
    main()
