"""Quickstart: run the full MultiScope workflow on one synthetic dataset.

    PYTHONPATH=src python examples/quickstart.py

Trains the detector/proxy/tracker stack, selects θ_best, runs the greedy
tuner, and prints the speed-accuracy curve — Figure 1's workflow end to
end in a few minutes on CPU.  The last section is the serving story:
pre-process the test split ONCE into a ``TrackStore``, then answer an
open-ended stream of queries from the materialized tracks in
milliseconds (``repro.query``), live segment appends with standing
queries (``repro.stream``), two cameras ingesting concurrently through
one shared ``executor.BatchBroker`` — their per-frame detector windows
coalesce into consolidated device batches while each feed's tracks
stay bit-identical to its solo run — and the device-resident TRACK
stage (``ExecutorOptions(device_tracker=True)``): the fused
``track_step`` kernel scanning whole chunks in one dispatch, still
bit-identical to the host tracker.
"""
import dataclasses
import os
import sys
import tempfile
import threading

sys.path.insert(0, "src")

import numpy as np  # noqa: E402

from repro import obs  # noqa: E402
from repro.configs.multiscope import MULTISCOPE_PIPELINE  # noqa: E402
from repro.core import tuner as tuner_mod  # noqa: E402
from repro.core.executor import (BatchBroker, ExecutorOptions,  # noqa: E402
                                 run_clips)
from repro.core.metrics import clip_count_accuracy  # noqa: E402
from repro.data.video_synth import make_clip, make_split  # noqa: E402
from repro.query import Query, QueryService, TrackStore  # noqa: E402
from repro.stream import SegmentIngestor, StandingQuery  # noqa: E402


def main() -> None:
    cfg = MULTISCOPE_PIPELINE.reduced()
    train = make_split("caldot1", "train", 4)
    val = make_split("caldot1", "val", 3)
    test = make_split("caldot1", "test", 3)

    print("== setup (detector / θ_best / proxies / windows / tracker) ==")
    system = tuner_mod.setup(cfg, train, val, detector_steps=250,
                             tracker_steps=800)

    print("\n== greedy joint tuning (§3.5) ==")
    curve = tuner_mod.tune(system, val)

    print("\n== the speed-accuracy curve, applied to the TEST split ==")
    # the streaming executor runs the whole split: decode prefetch is on
    # by default, and clip i+1's decode overlaps clip i's compute
    for pt in curve:
        results, secs = run_clips(system.bank, pt.params, test)
        accs = [clip_count_accuracy(r.tracks, clip)
                for r, clip in zip(results, test)]
        acc = sum(accs) / len(accs)
        print(f"  [{pt.module:10s}] test_acc={acc:.3f} "
              f"test_t={secs:6.2f}s  {pt.params.describe()}")

    print("\n== pre-process once, query many (repro.query) ==")
    # materialize the split once: TrackStore streams cold clips through
    # the executor and persists the tracks keyed by θ's fingerprint —
    # point the root at a persistent directory and a re-run skips
    # straight to the queries
    with tempfile.TemporaryDirectory(prefix="trackstore_") as root:
        store = TrackStore(root, system.bank, system.theta_best)
        service = QueryService(store)
        report = service.warm(test)
        print(f"  ingest: {report.ingested} clips, {report.frames} "
              f"frames ({report.fps:.0f} fps wall)")
        # ...then every query is a millisecond scan, detector untouched
        for desc, q in [
            ("frames with >=2 objects",
             Query.count_frames(min_count=2)),
            ("busy frames in the top half",
             Query.count_frames(region=(0.0, 0.0, 1.0, 0.5),
                                min_count=2)),
            ("first 5 such frames",
             Query.limit_frames(min_count=2, want=5,
                                min_spacing=test[0].profile.fps)),
        ]:
            r = service.query(q, test)
            answer = r.frames if q.aggregate == "frames" \
                else int(r.aggregates["count"])
            # skipped = clips the per-clip index summaries proved
            # irrelevant; indexed = clips answered from precomputed
            # count histograms without touching a row
            print(f"  {desc}: {answer} "
                  f"({r.stats.scan_seconds * 1e3:.2f}ms, "
                  f"{r.skipped_clips} skipped / {r.indexed_clips} "
                  f"indexed of {r.n_clips})")

        print("\n== live ingestion (repro.stream) ==")
        # an always-on camera appends SEGMENTS to an open clip; queries
        # stay answerable at every watermark in between, and a standing
        # query receives exact per-watermark deltas instead of being
        # re-run from scratch
        live = make_clip("caldot1", "live", 0, n_frames=48)
        ingestor = SegmentIngestor(store, service=service)
        watching = service.register_standing(StandingQuery(
            Query.count_frames(min_count=2), [live],
            name="busy-frames"))
        ingestor.open(live)
        while True:
            rep = ingestor.append(live, 12)     # one camera segment
            delta = watching.deltas[-1]
            print(f"  watermark {rep.watermark:2d}: "
                  f"+{delta.count_delta} busy frames "
                  f"(append {rep.wall_seconds * 1e3:.0f}ms, "
                  f"delta {rep.standing_seconds * 1e3:.2f}ms, "
                  f"{delta.rows_scanned} new rows scanned)")
            if rep.sealed:
                break
        # the accumulated standing answer == re-running ad-hoc
        total = int(watching.result().aggregates["count"])
        adhoc = int(service.query(Query.count_frames(min_count=2),
                                  [live]).aggregates["count"])
        print(f"  sealed: {total} busy frames accumulated "
              f"(ad-hoc agrees: {adhoc == total})")

        print("\n== two cameras, one shared detector batch "
              "(BatchBroker) ==")
        # two live feeds decode, plan and track independently on their
        # own threads, but their per-frame detector windows coalesce
        # into shared device batches through one executor.BatchBroker:
        # fewer, fuller dispatches, while each feed's tracks stay
        # BIT-identical to its solo run (the broker invariant).
        # A proxy-on θ is the broker's regime — the proxy gates DETECT
        # down to a couple of small windows per frame, exactly the
        # tiny per-stream dispatches worth merging (θ_best may run
        # proxy-off, where every call is already a full frame). The
        # lowest sweep threshold keeps skipping conservative for the
        # demo; a production θ would calibrate it for target recall.
        res = sorted(system.bank.proxies)[-1]
        per_frame = dataclasses.replace(
            system.theta_best, chunk_size=1, refine=False,
            proxy_res=res, proxy_threshold=min(cfg.proxy.thresholds))
        feeds = [make_clip("caldot1", "live", i + 1, n_frames=24)
                 for i in range(2)]
        detector = system.bank.detectors[per_frame.det_arch]

        def ingest_feed(feed, tag, broker):
            s = TrackStore(os.path.join(root, f"{tag}_{feed.clip_id}"),
                           system.bank, per_frame)
            ing = SegmentIngestor(s, options=ExecutorOptions(
                prefetch=False, batch_broker=broker))
            ing.open(feed)
            while not ing.append(feed, 12).sealed:
                pass
            return s.get(feed).rows

        detector.dispatches = 0
        solo = [ingest_feed(f, "solo", None) for f in feeds]
        solo_dispatches = detector.dispatches
        # trace the rest of the demo: spans cost nothing until here
        # (every site guards on TRACER.enabled) and recording them
        # never changes tracks or dispatch counts (repro.obs contract)
        obs.enable()
        broker = BatchBroker()
        shared = [None, None]
        threads = [threading.Thread(
            target=lambda i=i: shared.__setitem__(
                i, ingest_feed(feeds[i], "brk", broker)))
            for i in range(2)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        broker.close()
        identical = all(np.array_equal(a, b)
                        for a, b in zip(solo, shared))
        print(f"  {broker.dispatches} consolidated detector dispatches "
              f"vs {solo_dispatches} solo "
              f"(mean bucket fill "
              f"{sum(broker.batch_fill) / len(broker.batch_fill):.2f}); "
              f"tracks bit-identical: {identical}")

        print("\n== device-resident TRACK (fused track-step kernel) ==")
        # with a recurrent θ, TRACK itself can live on the device: the
        # fused track_step kernel advances GRU + match + assignment in
        # one dispatch. ExecutorOptions(device_assign=True) calls it
        # per frame; device_tracker=True scans a WHOLE chunk in one
        # dispatch; a TrackBroker (same shape as BatchBroker above)
        # coalesces concurrent streams' steps. All are scheduling
        # knobs — tracks stay bit-identical to the host tracker, so
        # none of them is part of θ.
        from repro.core.executor import run_clip_streamed
        recur = dataclasses.replace(per_frame, tracker="recurrent",
                                    chunk_size=8)
        host = run_clip_streamed(system.bank, recur, feeds[0])
        dev = run_clip_streamed(system.bank, recur, feeds[0],
                                ExecutorOptions(device_tracker=True))
        identical = len(host.tracks) == len(dev.tracks) and all(
            np.array_equal(a, b)
            for a, b in zip(host.tracks, dev.tracks))
        print(f"  host {host.dispatches['track']} track dispatches -> "
              f"device {dev.dispatches['track']} (chunk-scan); "
              f"tracks bit-identical: {identical}")
        t = dev.stage_seconds["track"]
        print(f"  track stage: {t['wall'] * 1e3:.0f}ms wall / "
              f"{t['process'] * 1e3:.0f}ms cpu "
              f"(RunResult.stage_seconds)")

        print("\n== one timeline for it all (repro.obs) ==")
        # everything since obs.enable() — the two-camera broker run,
        # both feeds' appends, and the device-track comparison — landed
        # in one span ring buffer.  Inspect it in-process...
        spans = obs.TRACER.snapshot()
        by_name = {}
        for s in spans:
            by_name[s.name] = by_name.get(s.name, 0) + 1
        print(f"  {len(spans)} spans: "
              + ", ".join(f"{n} x{c}"
                          for n, c in sorted(by_name.items())))
        flushes = [s for s in spans if s.name == "broker.detect.flush"]
        if flushes:
            f0 = max(flushes, key=lambda s: s.args["windows"])
            print(f"  busiest flush: {f0.args['windows']} windows from "
                  f"{f0.args['streams']} streams after "
                  f"{f0.args['wait_ms']:.1f}ms linger")
        # ...read the always-on metrics registry the same way...
        fill = obs.REGISTRY.snapshot("broker.detect.fill")
        if fill.get("broker.detect.fill", {}).get("count"):
            f = fill["broker.detect.fill"]
            print(f"  broker fill: mean {f['mean']:.2f} over "
                  f"{f['count']} dispatches (REGISTRY)")
        # ...and export the timeline: the Chrome trace renders each
        # camera as its own lane with the shared broker lane between
        # them (open in chrome://tracing or https://ui.perfetto.dev)
        trace = os.path.join(tempfile.gettempdir(),
                             "multiscope_trace.json")
        jsonl = os.path.join(tempfile.gettempdir(),
                             "multiscope_spans.jsonl")
        obs.export_chrome(trace)
        obs.export_jsonl(jsonl)
        obs.disable()
        print(f"  wrote {trace} (Chrome trace) and {jsonl} "
              f"(JSON-lines)")

        print("\n== the same telemetry over HTTP (obs.serve) ==")
        # the serving plane: a background stdlib exporter mounting
        # Prometheus /metrics, component-health /healthz (with the SLO
        # engine's rolling-window verdicts) and a full JSON /snapshot.
        # It costs nothing until start()ed, and a concurrent scraper
        # never perturbs tracks — the same no-perturbation contract as
        # tracing, asserted in tests/test_obs_serve.py
        import json
        import urllib.request

        from repro.obs.serve import ObsServer
        from repro.obs.slo import SloEngine

        with ObsServer(port=0, slo=SloEngine()) as server:
            text = urllib.request.urlopen(
                server.url + "/metrics", timeout=5).read().decode()
            hz = json.loads(urllib.request.urlopen(
                server.url + "/healthz", timeout=5).read().decode())
        sample = next((ln for ln in text.splitlines()
                       if ln.startswith("stream_appends")),
                      text.splitlines()[-1])
        print(f"  GET /metrics: {len(text.splitlines())} exposition "
              f"lines, e.g. `{sample}`")
        comps = ", ".join(f"{n}={c['status']}"
                          for n, c in hz["components"].items())
        print(f"  GET /healthz: {hz['status']} ({comps})")


if __name__ == "__main__":
    main()
