"""Train a ~100M-parameter LM for a few hundred steps on the synthetic
bigram corpus — the end-to-end training driver over the same substrate
the dry-run lowers at production scale (AdamW, grad clip, checkpointing,
crash-safe supervisor, skippable data pipeline).

    PYTHONPATH=src python examples/train_lm.py [--steps 300]

The loss should descend from ~log(vocab) toward the bigram entropy floor
printed at startup — proof the whole stack trains.
"""
import argparse
import dataclasses
import sys
import time

sys.path.insert(0, "src")

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.configs import get_config  # noqa: E402
from repro.data.tokens import TokenPipeline  # noqa: E402
from repro.distributed.checkpoint import Checkpointer  # noqa: E402
from repro.distributed.fault import Supervisor  # noqa: E402
from repro.models.model import build_model  # noqa: E402
from repro.optim import adamw, cosine_schedule  # noqa: E402
from repro.train import build_train_step  # noqa: E402


def make_100m_config():
    """qwen2-family config scaled to ~100M params."""
    base = get_config("qwen2-0.5b")
    return dataclasses.replace(
        base, name="qwen2-100m", n_layers=6, d_model=512, n_heads=8,
        n_kv_heads=2, head_dim=64, d_ff=1536, vocab_size=8192,
        remat="none")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt", default="artifacts/train_lm_ckpt")
    args = ap.parse_args()

    cfg = make_100m_config()
    model = build_model(cfg)
    params = model.init_params(0)
    n = model.param_count()
    print(f"model {cfg.name}: {n / 1e6:.1f}M params")

    pipe = TokenPipeline(cfg.vocab_size, args.batch, args.seq, seed=0)
    print(f"bigram entropy floor: {pipe.bigram_entropy():.3f} nats/token")

    opt = adamw(lr=cosine_schedule(3e-3, 30, args.steps))
    opt_state = opt.init(params)
    ts = build_train_step(model, opt, max_grad_norm=1.0)
    step_jit = jax.jit(lambda p, s, b: ts(p, s, b))

    sup = Supervisor(Checkpointer(args.ckpt, keep=2), checkpoint_every=100)
    t0 = time.time()
    losses = []

    def step_fn(state, step):
        p, s = state
        batch = {k: jnp.asarray(v) for k, v in pipe.batch_at(step).items()}
        p, s, mets = step_jit(p, s, batch)
        losses.append(float(mets["loss"]))
        if step % 25 == 0:
            avg = sum(losses[-25:]) / len(losses[-25:])
            tok_s = args.batch * args.seq * (step + 1) / (time.time() - t0)
            print(f"step {step:4d} loss {avg:7.4f} "
                  f"({tok_s:,.0f} tok/s)")
        return (p, s)

    params, opt_state = sup.run((params, opt_state), step_fn, 0,
                                args.steps)
    final = sum(losses[-20:]) / 20
    print(f"\nfinal loss {final:.4f} (floor {pipe.bigram_entropy():.3f}, "
          f"start ~{losses[0]:.2f})")


if __name__ == "__main__":
    main()
