"""Table 2 scenario: a cardinality-limited query answered two ways —
BlazeIt's query-driven search vs MultiScope's extract-once-serve-many
track store.

    PYTHONPATH=src python examples/limit_query.py

Find N frames with >= K cars in the bottom half of the jackson dataset.
MultiScope pre-processes once — ``TrackStore.ingest`` streams the query
set through the executor (decode prefetch on by default) and
materializes the tracks on disk — after which THIS query and every
follow-up query run in milliseconds over the packed track arrays
(``QueryService``), while BlazeIt must touch the detector per query.
"""
import sys
import tempfile

sys.path.insert(0, "src")

from repro.configs.multiscope import MULTISCOPE_PIPELINE  # noqa: E402
from repro.core import tuner as tuner_mod  # noqa: E402
from repro.core.baselines import BlazeItBaseline  # noqa: E402
from repro.core.experiment import limit_query_experiment  # noqa: E402
from repro.data.video_synth import make_split  # noqa: E402
from repro.query import (Query, QueryService, StoreBudget,  # noqa: E402
                         TimeRange, TrackStore)


def main() -> None:
    cfg = MULTISCOPE_PIPELINE.reduced()
    train = make_split("jackson", "train", 4)
    val = make_split("jackson", "val", 3)
    query_clips = make_split("jackson", "test", 8)

    system = tuner_mod.setup(cfg, train, val, detector_steps=250,
                             tracker_steps=800)
    tuner_mod.tune(system, val)

    blaze = BlazeItBaseline(system.bank)
    det = system.bank.detectors[system.theta_best.det_arch]
    train_dets = []
    for clip in train:
        for f in range(0, clip.n_frames, system.theta_best.gap):
            frame = clip.render(f, *system.theta_best.det_res)
            d = det.detect_batch(frame[None],
                                 system.theta_best.det_conf)[0]
            train_dets.append((clip, f, d))
    blaze.train(train_dets)

    # -- Table 2: the same limit query, both systems ------------------------
    res = limit_query_experiment(system, blaze, query_clips,
                                 want=8, min_count=2)
    print("\n== Table 2 analogue ==")
    for m in ("blazeit", "multiscope"):
        d = res[m]
        total = d["pre_seconds"] + d["query_seconds"]
        print(f"{m:11s}: pre={d['pre_seconds']:.1f}s "
              f"query={d['query_seconds']:.3f}s total={total:.1f}s "
              f"correct={d['correct']}/{res['want']}")
    print(f"{'':11s}  warm repeat of the same query: "
          f"{res['multiscope']['warm_query_seconds'] * 1e3:.2f}ms")

    # -- exploratory follow-ups: the store answers NEW queries for free -----
    with tempfile.TemporaryDirectory(prefix="trackstore_") as root:
        store = TrackStore(root, system.bank, system.theta_best)
        service = QueryService(store)
        service.warm(query_clips)         # pre-process once...
        followups = [
            ("frames with >=2 cars in the bottom half",
             Query.count_frames(region=(0.0, 0.5, 1.0, 1.0),
                                min_count=2)),
            ("seconds with any car in the left half",
             Query.duration(region=(0.0, 0.0, 0.5, 1.0))),
            ("distinct tracks in the first 3 seconds",
             Query.count_tracks(time_range=TimeRange(
                 0, 3 * query_clips[0].profile.fps))),
        ]
        print("\n== exploratory follow-ups (warm store, no detector) ==")
        for desc, q in followups:         # ...query many
            r = service.query(q, query_clips)
            val_str = ", ".join(f"{k}={v:.2f}" if isinstance(v, float)
                                else f"{k}={v}"
                                for k, v in r.aggregates.items())
            print(f"  {desc}: {val_str}  "
                  f"({r.stats.scan_seconds * 1e3:.2f}ms, "
                  f"ingested {r.stats.ingested_clips} clips)")

        # -- the index at work: a selective region is answered without
        # scanning (or even loading) the clips it provably misses
        sel = Query.count_frames(region=(0.0, 0.0, 0.02, 0.02))
        r = service.query(sel, query_clips)
        print(f"\n== secondary indexes ==\n"
              f"  far-corner count query: skipped "
              f"{r.skipped_clips}/{r.n_clips} clips via summaries, "
              f"scanned {r.scanned_clips} "
              f"({r.stats.scan_seconds * 1e3:.2f}ms)")
        r = service.query(Query.count_frames(min_count=2), query_clips)
        print(f"  unregioned count query: {r.indexed_clips} clips "
              f"answered straight from histograms")

        # -- and a size budget: evict LRU clips, re-query transparently
        budget = int(store.disk_bytes() * 0.5)
        evicted = store.set_budget(StoreBudget(max_bytes=budget))
        r = service.query(Query.count_frames(min_count=2), query_clips)
        print(f"  after a {budget} B budget: {evicted} clips evicted, "
              f"re-query re-ingested {r.stats.ingested_clips} and "
              f"matches: {r.aggregates}")


if __name__ == "__main__":
    main()
