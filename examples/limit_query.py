"""Table 2 scenario: a cardinality-limited query answered two ways —
BlazeIt's query-driven search vs MultiScope's extract-all-then-filter.

    PYTHONPATH=src python examples/limit_query.py

Find N frames with >= K cars in the bottom half of the jackson dataset.
MultiScope pre-processes once — the extract-all pass goes through the
streaming executor (``executor.run_clips``, decode prefetch on by
default) — and the query itself runs in milliseconds over extracted
tracks, while BlazeIt must touch the detector per query.
"""
import sys

sys.path.insert(0, "src")

from repro.configs.multiscope import MULTISCOPE_PIPELINE  # noqa: E402
from repro.core import tuner as tuner_mod  # noqa: E402
from repro.core.baselines import BlazeItBaseline  # noqa: E402
from repro.core.experiment import limit_query_experiment  # noqa: E402
from repro.data.video_synth import make_split  # noqa: E402


def main() -> None:
    cfg = MULTISCOPE_PIPELINE.reduced()
    train = make_split("jackson", "train", 4)
    val = make_split("jackson", "val", 3)
    query_clips = make_split("jackson", "test", 8)

    system = tuner_mod.setup(cfg, train, val, detector_steps=250,
                             tracker_steps=800)
    tuner_mod.tune(system, val)

    blaze = BlazeItBaseline(system.bank)
    det = system.bank.detectors[system.theta_best.det_arch]
    train_dets = []
    for clip in train:
        for f in range(0, clip.n_frames, system.theta_best.gap):
            frame = clip.render(f, *system.theta_best.det_res)
            d = det.detect_batch(frame[None],
                                 system.theta_best.det_conf)[0]
            train_dets.append((clip, f, d))
    blaze.train(train_dets)

    res = limit_query_experiment(system, blaze, query_clips,
                                 want=8, min_count=2)
    print("\n== Table 2 analogue ==")
    for m in ("blazeit", "multiscope"):
        d = res[m]
        total = d["pre_seconds"] + d["query_seconds"]
        print(f"{m:11s}: pre={d['pre_seconds']:.1f}s "
              f"query={d['query_seconds']:.3f}s total={total:.1f}s "
              f"correct={d['correct']}/{res['want']}")


if __name__ == "__main__":
    main()
