"""Track store + exploratory query engine tests.

Covers the subsystem's three contracts:

  * **materialize once** — packed-array roundtrip is exact, the store
    persists across process boundaries (fresh store over the same
    root), re-ingest of a warm split performs zero detector dispatches;
  * **θ versioning** — track-relevant θ changes invalidate, the
    scheduling-only ``chunk_size`` does not, ``prune`` drops stale
    versions;
  * **query equivalence** — the compiled vectorized plan returns
    exactly what the original inline ``limit_query_experiment`` scan
    returned, concurrent queries agree, aggregates match hand
    computation.
"""
import dataclasses
import threading

import numpy as np
import pytest

from repro.core.executor import run_clips
from repro.query import (CountAtLeast, Limit, PackedTracks, Query,
                         QueryService, Region, TimeRange, TrackFilter,
                         TrackStore, compile_query, theta_fingerprint)
from repro.query.ref import reference_limit_scan

# the shared `qsys` fixture (trained bank + warm store over 3 caldot1
# clips) lives in conftest.py — tests/test_query_index.py uses it too


# ---------------------------------------------------------------------------
# Packing
# ---------------------------------------------------------------------------

def _fake_tracks():
    t0 = np.array([[0, 0.1, 0.2, 0.05, 0.05, 0],
                   [2, 0.2, 0.3, 0.05, 0.05, 0],
                   [4, 0.3, 0.4, 0.05, 0.05, 0]], np.float32)
    t1 = np.array([[1, 0.8, 0.9, 0.04, 0.04, 1],
                   [2, 0.7, 0.8, 0.04, 0.04, 1]], np.float32)
    return [t0, t1]


class _FakeClip:
    class profile:
        name = "fake"
        fps = 8
    split, clip_id, n_frames = "test", 0, 8


def test_pack_roundtrip():
    tracks = _fake_tracks()
    packed = PackedTracks.pack(tracks, _FakeClip())
    assert packed.n_tracks == 2
    assert packed.rows.shape == (5, 6)
    np.testing.assert_array_equal(packed.lengths, [3, 2])
    np.testing.assert_array_equal(packed.row_track, [0, 0, 0, 1, 1])
    for orig, rt in zip(tracks, packed.tracks()):
        np.testing.assert_array_equal(orig, rt)


def test_pack_empty():
    packed = PackedTracks.pack([], _FakeClip())
    assert packed.n_tracks == 0
    assert packed.rows.shape == (0, 6)
    assert compile_query(Query()).run([(_FakeClip(), packed)]) \
        .aggregates["count"] == 0


# ---------------------------------------------------------------------------
# Store: persistence, incremental ingest, versioning
# ---------------------------------------------------------------------------

def test_store_matches_executor_output(qsys):
    bank, params, clips, store, _ = qsys
    results, _ = run_clips(bank, params, clips)
    for clip, r in zip(clips, results):
        stored = store.tracks(clip)
        assert len(stored) == len(r.tracks)
        for a, b in zip(r.tracks, stored):
            np.testing.assert_array_equal(a, b)


def test_store_persists_across_instances(qsys):
    bank, params, clips, _, root = qsys
    fresh = TrackStore(root, bank, params)
    assert all(fresh.has(c) for c in clips)
    report = fresh.ingest(clips)
    assert report.ingested == 0 and report.cached == len(clips)
    packed = fresh.get(clips[0])
    assert packed is not None and packed.n_frames == clips[0].n_frames


def test_reingest_zero_detector_calls(qsys):
    """The acceptance guarantee: a materialized split re-ingests with
    zero detector dispatches (and zero clips run)."""
    bank, params, clips, store, _ = qsys
    det = bank.detectors[params.det_arch]
    before = det.dispatches
    report = store.ingest(clips)
    assert report.ingested == 0
    assert det.dispatches == before


def test_fingerprint_versioning(qsys):
    bank, params, clips, _, root = qsys
    # scheduling-only fields do NOT change the fingerprint
    assert theta_fingerprint(params) == theta_fingerprint(
        dataclasses.replace(params, chunk_size=32))
    # track-relevant fields DO
    changed = dataclasses.replace(params, det_conf=params.det_conf + 0.1)
    assert theta_fingerprint(params) != theta_fingerprint(changed)
    store = TrackStore(root, bank, params)
    assert store.has(clips[0])
    store.set_params(changed)               # new version: everything cold
    assert not store.has(clips[0])
    store.set_params(params)                # back: warm again, from disk
    assert store.has(clips[0])


def test_prune_drops_stale_versions(qsys, tmp_path):
    bank, params, clips, _, _ = qsys
    root = str(tmp_path / "store")
    a = TrackStore(root, bank, params)
    a.ingest(clips[:1])
    changed = dataclasses.replace(params, gap=2)
    a.set_params(changed)
    a.ingest(clips[:1])
    a.set_params(params)
    removed = a.prune()
    assert removed == [theta_fingerprint(changed)]
    assert a.has(clips[0])                  # current version untouched
    a.set_params(changed)
    assert not a.has(clips[0])              # stale version gone from disk


# ---------------------------------------------------------------------------
# Plan: vectorized ops over handcrafted tracks
# ---------------------------------------------------------------------------

def _entries():
    return [(_FakeClip(), PackedTracks.pack(_fake_tracks(), _FakeClip()))]


def test_plan_region_and_count():
    # track 0 lives upper-left, track 1 lower-right
    q = Query((TrackFilter(min_len=2), Region(0.0, 0.0, 0.5, 0.5),
               CountAtLeast(1)), aggregate="count")
    assert compile_query(q).run(_entries()).aggregates["count"] == 3
    q2 = Query((Region(0.6, 0.6, 1.0, 1.0),), aggregate="count")
    assert compile_query(q2).run(_entries()).aggregates["count"] == 2


def test_plan_time_range_and_track_len():
    q = Query((TimeRange(2, None),), aggregate="count")
    assert compile_query(q).run(_entries()).aggregates["count"] == 2
    # min_len=3 drops the 2-row track entirely
    q2 = Query((TrackFilter(min_len=3),), aggregate="count")
    assert compile_query(q2).run(_entries()).aggregates["count"] == 3
    q3 = Query((TrackFilter(min_len=3),), aggregate="tracks")
    assert compile_query(q3).run(_entries()).aggregates["tracks"] == 1


def test_plan_limit_spacing_and_early_exit():
    entries = _entries() * 3                # 3 identical "clips"
    q = Query((CountAtLeast(1),), limit=Limit(3, min_spacing=2))
    res = compile_query(q).run(entries)
    # frames 0,1,2,4 match; spacing 2 keeps 0,2,4 -> limit hits in clip 0
    assert res.frames == [(0, 0), (0, 2), (0, 4)]
    assert res.scanned_clips == 1 and res.n_clips == 3


def test_plan_duration():
    q = Query((CountAtLeast(1),), aggregate="duration")
    res = compile_query(q).run(_entries())
    # frames 0,1,2,4 have >=1 point; fps=8
    assert res.aggregates["duration_seconds"] == pytest.approx(4 / 8)


def test_query_validation():
    with pytest.raises(ValueError):
        Query(aggregate="nope")
    with pytest.raises(TypeError):
        Query(("region",))
    with pytest.raises(ValueError):
        Limit(0)
    # a limited scan early-exits, so scalar aggregates under it would
    # be silently truncated — rejected at construction
    with pytest.raises(ValueError):
        Query((CountAtLeast(1),), limit=Limit(3), aggregate="count")
    # disjoint regions fold into a match-nothing plan, not an error
    q = Query((Region(0.0, 0.0, 0.2, 0.2), Region(0.8, 0.8, 1.0, 1.0)),
              aggregate="count")
    assert compile_query(q).run(_entries()).aggregates["count"] == 0
    # disjoint time ranges likewise
    q2 = Query((TimeRange(0, 2), TimeRange(3, 5)), aggregate="count")
    assert compile_query(q2).run(_entries()).aggregates["count"] == 0
    # and a limit query exposes no (partial) scalar aggregates
    q3 = Query((CountAtLeast(1),), limit=Limit(2))
    assert "count" not in compile_query(q3).run(_entries()).aggregates


# ---------------------------------------------------------------------------
# Service: inline-scan equivalence, concurrency, prefetch
# ---------------------------------------------------------------------------

def test_service_limit_query_matches_inline_scan(qsys):
    """Acceptance: warm-store QueryService limit query == the original
    inline limit_query_experiment scan, for several query shapes."""
    bank, params, clips, store, _ = qsys
    service = QueryService(store)
    all_tracks = [store.tracks(c) for c in clips]
    for want, min_count, region, spacing in [
            (8, 1, (0.0, 0.5, 1.0, 1.0), 4),
            (3, 2, (0.0, 0.0, 1.0, 1.0), 0),
            (5, 1, (0.25, 0.0, 0.75, 1.0), 2)]:
        q = Query.limit_frames(region=region, min_count=min_count,
                               want=want, min_spacing=spacing)
        res = service.query(q, clips)
        assert res.stats.ingested_clips == 0
        assert res.frames == reference_limit_scan(
            all_tracks, want, min_count, region, spacing)


def test_service_aggregates_match_manual(qsys):
    bank, params, clips, store, _ = qsys
    service = QueryService(store)
    region = (0.0, 0.5, 1.0, 1.0)
    res = service.query(Query.count_frames(region=region), clips)
    manual = 0
    for c in clips:
        per_frame = {}
        for tr in store.tracks(c):
            if len(tr) < 2:
                continue
            for row in tr:
                if region[0] <= row[1] <= region[2] \
                        and region[1] <= row[2] <= region[3]:
                    per_frame[int(row[0])] = per_frame.get(
                        int(row[0]), 0) + 1
        manual += sum(1 for n in per_frame.values() if n >= 1)
    assert res.aggregates["count"] == manual


def test_service_class_partition(qsys):
    """Per-class track counts partition the classifiable tracks."""
    bank, params, clips, store, _ = qsys
    service = QueryService(store)
    n_patterns = clips[0].profile.patterns()
    total = service.query(Query.count_tracks(min_track_len=2), clips) \
        .aggregates["tracks"]
    by_class = sum(
        service.query(Query.count_tracks(classes=(c,), min_track_len=2),
                      clips).aggregates["tracks"]
        for c in range(n_patterns))
    unclassified = service.query(
        Query.count_tracks(classes=(-1,), min_track_len=2), clips) \
        .aggregates["tracks"]
    assert by_class + unclassified == total


def test_service_concurrent_queries_agree(qsys):
    bank, params, clips, store, _ = qsys
    service = QueryService(store)
    q = Query.limit_frames(region=(0.0, 0.5, 1.0, 1.0), min_count=1,
                           want=6, min_spacing=2)
    expected = service.query(q, clips).frames
    results, errs = [], []

    def client():
        try:
            for _ in range(5):
                results.append(service.query(q, clips).frames)
        except BaseException as exc:
            errs.append(exc)

    threads = [threading.Thread(target=client) for _ in range(4)]
    for th in threads:
        th.start()
    for th in threads:
        th.join()
    assert not errs
    assert len(results) == 20
    assert all(r == expected for r in results)
    rep = service.latency_report()
    assert rep["queries"] == 21 and rep["warm_queries"] == 21


def test_service_warm_query_bypasses_ingest_lock(qsys):
    """A query over materialized clips must not queue behind an
    in-flight ingest of other clips (no head-of-line blocking)."""
    bank, params, clips, store, _ = qsys
    service = QueryService(store)
    q = Query.count_frames(min_count=1)
    expected = service.query(q, clips).aggregates
    with service._ingest_lock:          # simulate a long-running ingest
        done = []

        def warm_client():
            done.append(service.query(q, clips).aggregates)

        th = threading.Thread(target=warm_client)
        th.start()
        th.join(timeout=10.0)           # must finish while lock is held
        assert done == [expected]


def test_service_cold_then_warm_split(qsys, tmp_path):
    """First query pays ingest, repeats are pure scan; prefetch warms in
    the background."""
    bank, params, clips, _, _ = qsys
    store = TrackStore(str(tmp_path / "cold"), bank, params)
    service = QueryService(store)
    q = Query.count_frames(min_count=1)
    cold = service.query(q, clips[:2])
    assert cold.stats.ingested_clips == 2
    assert cold.stats.ingest_seconds > 0
    warm = service.query(q, clips[:2])
    assert warm.stats.ingested_clips == 0
    assert warm.aggregates == cold.aggregates
    th = service.prefetch(clips)            # remaining clip in background
    th.join()
    res = service.query(q, clips)
    assert res.stats.ingested_clips == 0


def test_prefetch_summary_aware_ordering(qsys):
    """With a query, prefetch warms never-materialized clips first,
    then unskippable clips by descending predicted scan cost, and
    summary-skippable clips last."""
    from repro.data.video_synth import make_clip
    from repro.query.plan import compile_query
    bank, params, clips, store, _ = qsys
    service = QueryService(store)
    cold = make_clip("caldot1", "test", 97, n_frames=24)  # no summary
    mixed = [clips[0], cold, clips[1], clips[2]]
    # no plan: cold-first, then biggest row counts
    order = service._prefetch_order(mixed, None)
    assert order[0] is cold
    rows = [store.summary(c).n_rows for c in order[1:]]
    assert rows == sorted(rows, reverse=True)
    # a skip-everything region pushes all summarized clips to the back
    plan = compile_query(Query.count_frames(
        region=(0.0, 0.0, 0.02, 0.02)))
    order2 = service._prefetch_order(mixed, plan)
    assert order2[0] is cold
    assert all(plan.can_skip(store.summary(c)) for c in order2[1:])
    # prefetch(q=...) threads the ordering through to warm
    th = service.prefetch([clips[0]], q=Query.count_frames())
    th.join()
