"""Cross-stream BatchBroker: per-stream tracks must be BIT-identical
with the broker on vs off for every stream count / chunk size, detector
dispatches must consolidate, and the edge cases (zero-window flush,
single-window buckets, a stream failing mid-flight, drain-on-close) must
neither deadlock nor leak into other streams."""
import threading

import numpy as np
import pytest

from repro.configs.multiscope import MULTISCOPE_PIPELINE
from repro.core import pipeline as pl
from repro.core.executor import (BatchBroker, BrokerCancelled,
                                 ExecutorOptions, run_clip_streamed)
from repro.core.proxy import ProxyModel
from repro.core.tracker import init_tracker
from repro.core.train_models import train_detector
from repro.data.video_synth import make_split


@pytest.fixture(scope="module")
def exec_bank():
    cfg = MULTISCOPE_PIPELINE.reduced()
    clips = make_split("caldot1", "train", 2, n_frames=24)
    det, _ = train_detector("ssd-lite", clips,
                            [cfg.detector.resolutions[-1]], steps=60)
    bank = pl.ModelBank(cfg, {"ssd-lite": det, "ssd-deep": det})
    res = cfg.proxy.resolutions[-1]
    proxy = ProxyModel(cfg.proxy.cell, cfg.proxy.base_channels, res)
    bank.proxies = {res: proxy}
    bank.sizes_cells = [pl.det_grid(cfg.detector.resolutions[-1]),
                        (3, 2), (5, 3)]
    bank.ref_grid = pl.det_grid(cfg.detector.resolutions[-1])
    bank.tracker_params = init_tracker(cfg.tracker)
    W, H = cfg.detector.resolutions[-1]
    frame, _ = pl.render_frame(clips[0], 0, W, H)
    s, _ = proxy.scores(pl._downsample(frame, res))
    return bank, clips, res, float(np.quantile(s, 0.85))


def _params(bank, res, th, **kw):
    base = dict(det_arch="ssd-lite",
                det_res=bank.cfg.detector.resolutions[-1],
                det_conf=0.4, gap=1, proxy_res=res, proxy_threshold=th,
                tracker="sort", refine=False)
    base.update(kw)
    return pl.PipelineParams(**base)


def _run_streams(bank, params, clips, n_streams, broker):
    """Run n_streams concurrent clip executions (clips round-robin),
    each on its own thread, sharing ``broker`` (or none)."""
    results = [None] * n_streams
    errors = []

    def one(i):
        try:
            opts = ExecutorOptions(prefetch=False, batch_broker=broker)
            results[i] = run_clip_streamed(
                bank, params, clips[i % len(clips)], opts)
        except BaseException as exc:     # surfaced by the main thread
            errors.append(exc)

    threads = [threading.Thread(target=one, args=(i,))
               for i in range(n_streams)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errors, errors
    return results


def _assert_same(a, b):
    assert a.frames_processed == b.frames_processed
    assert a.detector_windows == b.detector_windows
    assert a.full_frames == b.full_frames
    assert a.skipped_frames == b.skipped_frames
    assert len(a.tracks) == len(b.tracks)
    for x, y in zip(a.tracks, b.tracks):
        np.testing.assert_array_equal(x, y)


@pytest.mark.parametrize("n_streams,chunk", [
    (1, 1), (1, 16), (4, 1), (4, 16), (16, 1), (16, 16),
])
def test_broker_bit_identity(exec_bank, n_streams, chunk):
    """The tentpole invariant: every stream's tracks are bit-identical
    to its solo broker-off run, for 1/4/16 concurrent streams and
    per-frame (chunk=1, single-window buckets) and chunked plans."""
    bank, clips, res, th = exec_bank
    params = _params(bank, res, th, chunk_size=chunk)
    ref = [run_clip_streamed(bank, params, c,
                             ExecutorOptions(prefetch=False))
           for c in clips]
    broker = BatchBroker()
    got = _run_streams(bank, params, clips, n_streams, broker)
    broker.close()
    for i, r in enumerate(got):
        _assert_same(r, ref[i % len(clips)])
    assert broker._registered == 0          # every handle released
    assert all(0.0 < f <= 1.0 for f in broker.batch_fill)


def test_broker_consolidates_dispatches(exec_bank):
    """At 4 streams the consolidated detector call count must be
    STRICTLY below the sum of the per-stream broker-off counts."""
    bank, clips, res, th = exec_bank
    params = _params(bank, res, th, chunk_size=16)
    det = bank.detectors[params.det_arch]
    det.dispatches = 0
    for c in (clips * 2):
        run_clip_streamed(bank, params, c, ExecutorOptions(prefetch=False))
    solo = det.dispatches
    broker = BatchBroker()
    _run_streams(bank, params, clips, 4, broker)
    broker.close()
    assert broker.dispatches < solo
    assert broker.windows_in > 0


class _FakeDetector:
    """detect_batch stub: one (1, 2) row per valid window encoding
    (origin, scale) so routing back to the right request is checkable."""

    def __init__(self):
        self.calls = 0

    def detect_batch(self, frames, conf, origins, scales, n_valid):
        self.calls += 1
        assert len(origins) == len(scales) == n_valid
        return [np.array([[float(origins[i][0]), float(scales[i])]])
                for i in range(n_valid)]


def _win(n):
    return np.zeros((n, 4, 4, 3), np.float32)


def test_broker_zero_windows_is_a_noop():
    """n_valid=0 returns [] without registering a pending request (a
    skip-heavy stream never delays anyone's flush)."""
    broker = BatchBroker()
    h = broker.register()
    det = _FakeDetector()
    assert h.detect(det, _win(0), 0.4, [], [], n_valid=0) == []
    assert broker.dispatches == 0 and not broker._pending
    h.close()
    broker.close()


def test_broker_single_window_bucket():
    """A lone 1-window request flushes (all-registered-pending trigger)
    into a bucket of one, fill 1.0."""
    broker = BatchBroker()
    h = broker.register()
    det = _FakeDetector()
    out = h.detect(det, _win(1), 0.4, [(7, 0)], [2.0], n_valid=1)
    assert len(out) == 1
    np.testing.assert_array_equal(out[0], [[7.0, 2.0]])
    assert broker.dispatches == 1 and broker.batch_fill == [1.0]
    h.close()
    broker.close()


def test_broker_routes_multi_stream_batches():
    """Two streams' same-shape requests consolidate into ONE detector
    call and split back per stream in submit order."""
    broker = BatchBroker(linger_ms=200.0)
    ha, hb = broker.register(), broker.register()
    det = _FakeDetector()
    out = {}

    def run(name, h, origins):
        out[name] = h.detect(det, _win(len(origins)), 0.4, origins,
                             [1.0] * len(origins), n_valid=len(origins))

    ta = threading.Thread(target=run, args=("a", ha, [(1, 0), (2, 0)]))
    tb = threading.Thread(target=run, args=("b", hb, [(3, 0)]))
    ta.start(), tb.start()
    ta.join(), tb.join()
    assert det.calls == 1 and broker.dispatches == 1
    assert [r[0][0] for r in out["a"]] == [1.0, 2.0]
    assert [r[0][0] for r in out["b"]] == [3.0]
    ha.close(), hb.close()
    broker.close()


def test_broker_stream_failure_mid_flight():
    """Unregistering a stream with a request pending raises
    BrokerCancelled on ITS thread only; the surviving stream's next
    request is served normally."""
    broker = BatchBroker(linger_ms=60000.0)     # no linger rescue
    ha, hb = broker.register(), broker.register()
    det = _FakeDetector()
    caught = []
    submitted = threading.Event()

    def doomed():
        with broker._cv:
            submitted.set()
        try:
            ha.detect(det, _win(1), 0.4, [(9, 0)], [1.0], n_valid=1)
        except BrokerCancelled as exc:
            caught.append(exc)

    t = threading.Thread(target=doomed)
    t.start()
    submitted.wait(10)
    # wait until the request is actually pending, then drop the stream
    for _ in range(1000):
        with broker._cv:
            if broker._pending:
                break
        threading.Event().wait(0.005)
    ha.close()
    t.join(10)
    assert not t.is_alive() and len(caught) == 1
    assert det.calls == 0                       # its windows were dropped
    out = hb.detect(det, _win(1), 0.4, [(5, 0)], [1.0], n_valid=1)
    np.testing.assert_array_equal(out[0], [[5.0, 1.0]])
    hb.close()
    broker.close()


def test_broker_flush_spans_ledger():
    """Tracing on: concurrent flushes emit one ``broker.detect.flush``
    span per flush with its consolidated ``broker.detect.dispatch``
    children parented to it and nested inside its interval, and the
    dispatch spans' window counts form an exact ledger — per flush they
    sum to the flush's recorded total, and across the run to every
    window any stream submitted."""
    from repro.obs.trace import TRACER

    broker = BatchBroker(linger_ms=50.0)
    det = _FakeDetector()
    n_streams, rounds = 6, 4
    handles = [broker.register() for _ in range(n_streams)]
    TRACER.enable()
    TRACER.clear()
    try:
        errors = []

        def feed(i):
            try:
                for r in range(rounds):
                    n = 1 + (i + r) % 3
                    origins = [(i * 100 + r * 10 + j, 0)
                               for j in range(n)]
                    out = handles[i].detect(
                        det, _win(n), 0.4, origins, [1.0] * n, n_valid=n)
                    # responses routed back to the right stream
                    assert [o[0][0] for o in out] \
                        == [float(og[0]) for og in origins]
            except BaseException as exc:     # surfaced after join
                errors.append(exc)

        threads = [threading.Thread(target=feed, args=(i,))
                   for i in range(n_streams)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        assert not errors, errors
        spans = TRACER.snapshot()
    finally:
        for h in handles:
            h.close()
        broker.close()
        TRACER.disable()
        TRACER.clear()

    total_windows = sum(1 + (i + r) % 3
                        for i in range(n_streams) for r in range(rounds))
    flushes = {s.sid: s for s in spans
               if s.name == "broker.detect.flush"}
    disp = [s for s in spans if s.name == "broker.detect.dispatch"]
    assert flushes and disp
    assert len(disp) == broker.dispatches
    assert broker.windows_in == total_windows
    assert sum(s.args["windows"] for s in disp) == total_windows
    assert sum(f.args["windows"] for f in flushes.values()) \
        == total_windows
    # well-parented: every dispatch belongs to exactly one flush and
    # its interval nests inside that flush's interval
    by_parent = {}
    for s in disp:
        p = flushes.get(s.parent)
        assert p is not None, "dispatch span not parented to a flush"
        assert p.ts <= s.ts and s.ts + s.dur <= p.ts + p.dur
        by_parent[s.parent] = by_parent.get(s.parent, 0) \
            + s.args["windows"]
    for sid, w in by_parent.items():
        assert flushes[sid].args["windows"] == w


def test_broker_drain_on_close():
    """close() flushes whatever is pending before refusing new work."""
    broker = BatchBroker(linger_ms=60000.0)
    ha, hb = broker.register(), broker.register()     # hb never submits
    det = _FakeDetector()
    out = []

    def submit():
        out.append(ha.detect(det, _win(1), 0.4, [(4, 0)], [1.0],
                             n_valid=1))

    t = threading.Thread(target=submit)
    t.start()
    for _ in range(1000):
        with broker._cv:
            if broker._pending:
                break
        threading.Event().wait(0.005)
    broker.close()
    t.join(10)
    assert not t.is_alive()
    np.testing.assert_array_equal(out[0][0], [[4.0, 1.0]])
    assert broker.dispatches == 1
    with pytest.raises(RuntimeError):
        broker.register()
    with pytest.raises(RuntimeError):
        hb.detect(det, _win(1), 0.4, [(0, 0)], [1.0], n_valid=1)
