"""Telemetry serving-plane tests (repro.obs.serve / slo / recorder).

The contracts under test:

  * **exposition** — ``render_prometheus`` emits well-formed 0.0.4
    text: counters/gauges by value kind, histogram summaries with
    interpolated ``quantile=`` samples, ``[...]`` instances as
    ``stream=`` labels, provider dicts JSON-only;
  * **health** — declarative thresholds grade components ok/warn/fail,
    ratio components divide first, absent gauges report ok/None, and
    the HTTP layer maps ``fail`` to 503;
  * **SLO engine** — rolling-window quantiles fire alert EDGES only
    (warn -> page -> resolved, no re-fire on a steady breach), for
    histogram-window and gauge-sampled rules alike;
  * **flight recorder** — the on-disk ring stays bounded across
    rotation, ``poll`` captures span/metric deltas exactly once, and
    nested crash hooks merge into ONE dump carrying the failing span's
    lineage and the checkpoint pointer;
  * **no perturbation** — a scraper hammering ``/metrics`` +
    ``/healthz`` throughout a 16-stream broker ingest leaves tracks,
    dispatch counts, broker units and the stage-span ledger
    bit-identical to an unscraped run.
"""
import dataclasses
import json
import threading
import urllib.error
import urllib.request
from collections import Counter as TallyCounter

import numpy as np
import pytest

from repro.core.executor import BatchBroker, ExecutorOptions, \
    run_clip_streamed
from repro.obs import recorder as recorder_mod
from repro.obs.metrics import REGISTRY, Histogram, Registry, \
    interp_quantile
from repro.obs.recorder import FlightRecorder
from repro.obs.serve import ObsServer, health_report, render_prometheus
from repro.obs.serve.health import HealthComponent, default_components
from repro.obs.slo import AlertRule, SloEngine
from repro.obs.trace import TRACER


@pytest.fixture(autouse=True)
def _serve_clean():
    yield
    TRACER.disable()
    TRACER.clear()
    recorder_mod.uninstall()


def _get(url, timeout=5.0):
    with urllib.request.urlopen(url, timeout=timeout) as resp:
        return resp.status, resp.headers.get("Content-Type"), \
            resp.read().decode()


# ---------------------------------------------------------------------------
# interpolated quantiles + exposition rendering
# ---------------------------------------------------------------------------

def test_interp_quantile_interpolates():
    assert interp_quantile([], 0.95) == 0.0
    assert interp_quantile([7.0], 0.5) == 7.0
    assert interp_quantile([0.0, 10.0], 0.5) == 5.0
    vals = [float(i) for i in range(1, 101)]     # 1..100
    assert interp_quantile(vals, 0.50) == pytest.approx(50.5)
    assert interp_quantile(vals, 0.99) == pytest.approx(99.01)
    assert interp_quantile(vals, 1.0) == 100.0


def test_histogram_summary_has_interpolated_p99():
    h = Histogram()
    for i in range(1, 101):
        h.observe(float(i))
    s = h.summary()
    assert s["min"] == 1.0 and s["max"] == 100.0
    assert s["p50"] == pytest.approx(50.5)
    assert s["p95"] == pytest.approx(95.05)
    assert s["p99"] == pytest.approx(99.01)


def test_render_prometheus_kinds_labels_and_summaries():
    reg = Registry()
    reg.counter("stream.appends").inc(3)
    reg.gauge("store.bytes").set(12.5)
    reg.gauge("stream.watermark[caldot1/live0]").set(24.0)
    h = reg.histogram("query.scan_seconds")
    for v in (0.1, 0.2, 0.3, 0.4):
        h.observe(v)
    reg.provider("stream.drift[caldot1/live0]",
                 lambda: {"watermarks": 2})
    text = render_prometheus(reg.snapshot())
    lines = text.splitlines()
    assert "# TYPE stream_appends counter" in lines
    assert "stream_appends 3" in lines
    assert "# TYPE store_bytes gauge" in lines
    assert "store_bytes 12.5" in lines
    assert 'stream_watermark{stream="caldot1/live0"} 24.0' in lines
    assert "# TYPE query_scan_seconds summary" in lines
    assert 'query_scan_seconds{quantile="0.50"} 0.25' in lines
    assert "query_scan_seconds_count 4" in lines
    assert any(ln.startswith("query_scan_seconds_sum") for ln in lines)
    # provider dicts have no flat representation: JSON-only
    assert "drift" not in text
    # the CLI's validator agrees the whole payload is well-formed
    from repro.obs.__main__ import validate_exposition
    assert validate_exposition(text) >= 6


# ---------------------------------------------------------------------------
# component health
# ---------------------------------------------------------------------------

def test_health_thresholds_ratio_and_absent():
    comps = default_components()
    names = {c.name for c in comps}
    assert names == {"decode_pool", "broker_detect", "broker_track",
                     "ingest_lag", "store_budget"}
    # nothing registered: every component absent -> ok with value None
    doc = health_report({}, comps)
    assert doc["status"] == "ok"
    assert all(c["status"] == "ok" and c["value"] is None
               for c in doc["components"].values())
    snap = {"broker.detect.queue_depth": 100.0,       # warn band
            "stream.watermark_lag_seconds[a]": 1.0,
            "stream.watermark_lag_seconds[b]": 45.0,  # worst -> fail
            "store.bytes": 50.0, "store.budget_bytes": 100.0}
    doc = health_report(snap, comps)
    assert doc["components"]["broker_detect"]["status"] == "warn"
    assert doc["components"]["ingest_lag"]["status"] == "fail"
    assert doc["components"]["ingest_lag"]["value"] == 45.0
    assert doc["components"]["store_budget"]["value"] == 0.5
    assert doc["components"]["store_budget"]["status"] == "ok"
    assert doc["status"] == "fail"
    # ratio with a missing/zero denominator is absent, not unhealthy
    doc = health_report({"store.bytes": 50.0}, comps)
    assert doc["components"]["store_budget"]["value"] is None


def test_server_routes_and_healthz_503(tmp_path):
    reg = Registry()
    reg.counter("stream.appends").inc()
    comps = [HealthComponent("broker_detect",
                             metric="broker.detect.queue_depth",
                             warn=10.0, fail=100.0)]
    with ObsServer(port=0, registry=reg, components=comps) as server:
        status, ctype, text = _get(server.url + "/metrics")
        assert status == 200
        assert ctype.startswith("text/plain; version=0.0.4")
        assert "stream_appends 1" in text
        status, _, body = _get(server.url + "/healthz")
        assert status == 200
        assert json.loads(body)["status"] == "ok"
        status, _, body = _get(server.url + "/snapshot")
        doc = json.loads(body)
        assert doc["metrics"]["stream.appends"] == 1
        assert doc["slo"] is None
        with pytest.raises(urllib.error.HTTPError) as ei:
            _get(server.url + "/nothing")
        assert ei.value.code == 404
        assert "/metrics" in json.loads(ei.value.read().decode())["routes"]
        # drive the watched gauge past fail: /healthz flips to 503
        reg.gauge("broker.detect.queue_depth").set(500.0)
        with pytest.raises(urllib.error.HTTPError) as ei:
            _get(server.url + "/healthz")
        assert ei.value.code == 503
        assert json.loads(ei.value.read().decode())["status"] == "fail"
    # stopped: the port no longer answers
    with pytest.raises(OSError):
        _get(server.url + "/metrics", timeout=0.5)


def test_server_costs_nothing_until_started():
    before = {t.name for t in threading.enumerate()}
    ObsServer(port=0)                      # constructed, never started
    after = {t.name for t in threading.enumerate()}
    assert "repro-obs-serve" not in after
    assert after == before


# ---------------------------------------------------------------------------
# the SLO engine
# ---------------------------------------------------------------------------

def test_slo_edges_warn_page_resolved(tmp_path):
    reg = Registry()
    rec = FlightRecorder(str(tmp_path / "ring"))
    rule = AlertRule("append_latency", "stream.append.wall_seconds",
                     objective=1.0, quantile=0.95, budget=0.25,
                     min_samples=4)
    eng = SloEngine([rule], registry=reg, recorder=rec)
    h = reg.histogram("stream.append.wall_seconds")

    assert eng.tick() == []                      # under min_samples
    for _ in range(8):
        h.observe(0.5)
    assert eng.tick() == []                      # healthy
    assert eng.report()["rules"]["append_latency"]["state"] == "ok"

    h.observe(5.0)                               # p95 breaches, 1/9 bad
    fired = eng.tick()
    assert [e.severity for e in fired] == ["warn"]
    assert fired[0].value > 1.0
    assert eng.tick() == []                      # steady breach: no re-fire

    for _ in range(3):
        h.observe(5.0)                           # 4/12 bad: budget blown
    fired = eng.tick()
    assert [e.severity for e in fired] == ["page"]
    assert fired[0].budget_remaining <= 0.0

    h.reset()
    for _ in range(8):
        h.observe(0.1)
    fired = eng.tick()
    assert [e.severity for e in fired] == ["resolved"]

    sev = [r["severity"] for r in rec.tail(50) if r["kind"] == "alert"]
    assert sev == ["warn", "page", "resolved"]
    assert [e.severity for e in eng.recent_events()] \
        == ["warn", "page", "resolved"]


def test_slo_gauge_rule_samples_instances_per_tick():
    reg = Registry()
    rule = AlertRule("ingest_watermark_lag",
                     "stream.watermark_lag_seconds[", objective=1.0,
                     quantile=0.5, budget=0.1, source="gauge",
                     window=16, min_samples=4)
    eng = SloEngine([rule], registry=reg)
    reg.gauge("stream.watermark_lag_seconds[a]").set(8.0)
    reg.gauge("stream.watermark_lag_seconds[b]").set(9.0)
    eng.tick()                                   # 2 samples: under min
    assert eng.report()["rules"]["ingest_watermark_lag"]["samples"] == 2
    fired = eng.tick()                           # 4 samples, all bad
    assert [e.severity for e in fired] == ["page"]
    for g in "ab":
        reg.gauge(f"stream.watermark_lag_seconds[{g}]").set(0.01)
    for _ in range(10):                          # recovery fills the window
        fired = eng.tick()
    assert eng.report()["rules"]["ingest_watermark_lag"]["state"] == "ok"


# ---------------------------------------------------------------------------
# the flight recorder
# ---------------------------------------------------------------------------

def test_ring_rotation_stays_bounded(tmp_path):
    rec = FlightRecorder(str(tmp_path / "ring"), segment_records=10,
                         segments=3)
    for i in range(100):
        rec.record("probe", i=i)
    files = rec._ring_files()
    assert len(files) <= 3
    tail = rec.tail(25)
    assert [r["i"] for r in tail] == list(range(75, 100))
    assert all(r["kind"] == "probe" for r in tail)


def test_poll_captures_span_and_metric_deltas_once(tmp_path):
    rec = FlightRecorder(str(tmp_path / "ring"))
    reg = Registry()
    tr = TRACER
    tr.enable()
    tr.clear()
    reg.counter("stream.appends").inc(2)
    with tr.span("stream.append", "stream", stream="camA"):
        pass
    got = rec.poll(tr, reg)
    assert got == {"spans": 1, "metrics": 1}
    assert rec.poll(tr, reg) == {"spans": 0, "metrics": 0}   # no re-emit
    reg.counter("stream.appends").inc()
    with tr.span("query.run", "query"):
        pass
    assert rec.poll(tr, reg) == {"spans": 1, "metrics": 1}
    kinds = [r["kind"] for r in rec.tail(50)]
    assert kinds.count("span") == 2 and kinds.count("metrics") == 2


def test_crash_dump_lineage_and_nested_merge(tmp_path):
    rec = FlightRecorder(str(tmp_path / "flight"))
    reg = Registry()
    reg.counter("stream.appends").inc()
    tr = TRACER
    tr.enable()
    tr.clear()
    try:
        with tr.span("run", "executor", stream="camA"):
            with tr.span("stream.append", "stream", stream="camA"):
                raise RuntimeError("boom")
    except RuntimeError as exc:
        # inner hook (no checkpoint yet), then outer hook enriches
        p1 = rec.dump("executor.drain", exc, tracer=tr, registry=reg)
        p2 = rec.dump("stream.append", exc, checkpoint="camA/ckpt.npz",
                      extra={"stream": "camA"}, tracer=tr, registry=reg)
    assert p1 == p2 and rec.dumps() == [p1]
    with open(p1) as f:
        doc = json.load(f)
    assert doc["reasons"] == ["executor.drain", "stream.append"]
    assert doc["checkpoint"] == "camA/ckpt.npz"
    assert doc["error"]["type"] == "RuntimeError"
    assert "boom" in doc["error"]["traceback"]
    names = [s["name"] for s in doc["lineage"]]
    assert names == ["stream.append", "run"]     # innermost first
    assert doc["metrics"]["stream.appends"] == 1
    # a different exception gets its own dump
    try:
        raise ValueError("other")
    except ValueError as exc:
        p3 = rec.dump("query.run", exc, tracer=tr, registry=reg)
    assert p3 != p1 and len(rec.dumps()) == 2


def test_crash_dump_module_hook_is_noop_without_recorder():
    recorder_mod.uninstall()
    assert recorder_mod.crash_dump("stream.append",
                                   RuntimeError("x")) is None
    assert recorder_mod.active() is None


# ---------------------------------------------------------------------------
# induced mid-append executor crash -> readable black box (acceptance)
# ---------------------------------------------------------------------------

def test_mid_append_executor_crash_writes_black_box(qsys, tmp_path,
                                                    monkeypatch):
    from repro.data.video_synth import make_clip
    from repro.query import TrackStore
    from repro.stream import SegmentIngestor

    bank, params, _, _, _ = qsys
    clip = make_clip("caldot1", "live", 7, n_frames=24)
    store = TrackStore(str(tmp_path / "crash_store"), bank, params)
    # prefetch off: no decode worker lingers past the induced crash
    ing = SegmentIngestor(store,
                          options=ExecutorOptions(prefetch=False))
    rec = recorder_mod.install(
        FlightRecorder(str(tmp_path / "flight")))
    TRACER.enable()
    TRACER.clear()
    ing.open(clip)
    ing.append(clip, 12)           # a good append lands a checkpoint

    def explode(*a, **k):
        raise RuntimeError("induced mid-append failure")

    monkeypatch.setattr(ing._executor.scheduler, "drain", explode)
    with pytest.raises(RuntimeError, match="induced"):
        ing.append(clip, 12)

    dumps = rec.dumps()
    assert len(dumps) == 1         # nested hooks merged into one dump
    with open(dumps[0]) as f:
        doc = json.load(f)
    assert doc["reasons"] == ["executor.drain", "stream.append"]
    assert doc["error"]["type"] == "RuntimeError"
    assert "induced mid-append failure" in doc["error"]["traceback"]
    # the failing span's lineage: the executor run that crashed,
    # innermost first, inside the append that drove it
    assert [s["name"] for s in doc["lineage"]] \
        == ["run", "stream.append"]
    assert doc["lineage"][0]["stream"] == "caldot1/live7"
    # the pointer an operator resumes the stream from
    assert doc["checkpoint"].endswith("ckpt.npz")
    import os
    assert os.path.exists(doc["checkpoint"])
    assert doc["extra"]["stream"] == "caldot1/live7"
    assert doc["extra"]["requested_frames"] == 12
    assert doc["metrics"]["stream.appends"] >= 1
    assert isinstance(doc["spans"], list)


# ---------------------------------------------------------------------------
# the no-perturbation contract under live scrape (acceptance)
# ---------------------------------------------------------------------------

def _broker_fleet(bank, params, clips, n_streams):
    """N concurrent per-frame streams sharing one BatchBroker; returns
    per-stream results in thread order."""
    broker = BatchBroker()
    results = [None] * n_streams
    errors = []

    def one(i):
        try:
            opts = ExecutorOptions(prefetch=False, batch_broker=broker)
            results[i] = run_clip_streamed(
                bank, params, clips[i % len(clips)], opts)
        except BaseException as exc:   # pragma: no cover - diagnostics
            errors.append(exc)

    threads = [threading.Thread(target=one, args=(i,))
               for i in range(n_streams)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    broker.close()
    assert not errors, errors
    return results


def _stage_ledger():
    """Per-stream multiset of (span name, chunk) for the deterministic
    span families (stage + run); broker flush/dispatch counts are
    timing-shaped and excluded."""
    ledger = {}
    for s in TRACER.snapshot():
        if s.name == "run" or s.name.startswith("stage."):
            ledger.setdefault(s.stream, TallyCounter())[
                (s.name, s.chunk)] += 1
    return ledger


def test_concurrent_scrape_never_perturbs_16_stream_ingest(qsys,
                                                           tmp_path):
    bank, params, clips, _, _ = qsys
    p1 = dataclasses.replace(params, chunk_size=1)
    n_streams = 16
    units = REGISTRY.counter("broker.detect.units_in")

    def one_run(scrape):
        TRACER.enable()
        TRACER.clear()
        units_before = units.value
        stop = threading.Event()
        scrapes = [0]
        server = scraper = None
        if scrape:
            rec = FlightRecorder(str(tmp_path / "scrape_ring"))
            server = ObsServer(port=0, slo=SloEngine(registry=REGISTRY),
                               recorder=rec).start()

            def hammer():
                while not stop.is_set():
                    for path in ("/metrics", "/healthz"):
                        try:
                            urllib.request.urlopen(
                                server.url + path, timeout=2).read()
                            scrapes[0] += 1
                        except Exception:
                            pass

            scraper = threading.Thread(target=hammer, daemon=True)
            scraper.start()
        try:
            results = _broker_fleet(bank, p1, clips, n_streams)
        finally:
            stop.set()
            if scraper is not None:
                scraper.join()
            if server is not None:
                server.stop()
        ledger = _stage_ledger()
        TRACER.disable()
        if scrape:
            assert scrapes[0] > 0, "scraper never completed a request"
        return results, units.value - units_before, ledger

    ref, ref_units, ref_ledger = one_run(scrape=False)
    got, got_units, got_ledger = one_run(scrape=True)

    for i, (a, b) in enumerate(zip(ref, got)):
        assert len(a.tracks) == len(b.tracks), i
        for x, y in zip(a.tracks, b.tracks):
            np.testing.assert_array_equal(x, y)
        assert a.dispatches == b.dispatches, i
        assert a.frames_processed == b.frames_processed, i
    assert got_units == ref_units
    assert got_ledger == ref_ledger


# ---------------------------------------------------------------------------
# the operator CLI
# ---------------------------------------------------------------------------

def test_cli_serve_smoke_writes_artifacts_and_dump(tmp_path, capsys):
    from repro.obs.__main__ import main as obs_main

    out = tmp_path / "smoke"
    assert obs_main(["serve-smoke", "--out", str(out)]) == 0
    for name in ("metrics.txt", "healthz.json", "snapshot.json"):
        assert (out / name).exists(), name
    health = json.loads((out / "healthz.json").read_text())
    assert health["status"] in ("ok", "warn", "fail")
    capsys.readouterr()

    assert obs_main(["dump", "--dir", str(out / "flight")]) == 0
    dump = json.loads(capsys.readouterr().out)
    assert dump["error"]["type"] == "ValueError"
    assert dump["checkpoint"] == "camA/ckpt.npz"

    assert obs_main(["tail", "--dir", str(out / "flight"),
                     "-n", "5"]) == 0
    lines = capsys.readouterr().out.strip().splitlines()
    assert 0 < len(lines) <= 5
    assert all(json.loads(ln)["kind"] for ln in lines)


def test_cli_scrape_and_snapshot_against_live_server(capsys):
    from repro.obs.__main__ import main as obs_main

    reg = Registry()
    reg.counter("query.count").inc(5)
    with ObsServer(port=0, registry=reg) as server:
        assert obs_main(["scrape", "--url", server.url]) == 0
        text = capsys.readouterr().out
        assert "# TYPE query_count counter" in text
        assert obs_main(["snapshot", "--url", server.url]) == 0
        doc = json.loads(capsys.readouterr().out)
        assert doc["metrics"]["query.count"] == 5
        assert doc["health"]["status"] == "ok"
