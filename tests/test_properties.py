"""Hypothesis property tests over the system's invariants."""
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.hungarian import hungarian, hungarian_batch, BIG
from repro.core.windows import (SizeSet, detector_time_model, group_cells,
                                plan_chunk, plan_from_mapped)
from repro.core.refine import resample_track
from repro.core.metrics import count_accuracy
from repro.launch.hlo_stats import _parse_shape


# ---------------------------------------------------------------------------
# Hungarian invariants
# ---------------------------------------------------------------------------

@settings(max_examples=60, deadline=None)
@given(st.integers(1, 7), st.integers(1, 7), st.integers(0, 10 ** 6))
def test_hungarian_is_valid_matching(n, m, seed):
    rng = np.random.default_rng(seed)
    cost = rng.random((n, m)) * 5
    pairs = hungarian(cost)
    assert len(pairs) == min(n, m)
    assert len({r for r, _ in pairs}) == len(pairs)
    assert len({c for _, c in pairs}) == len(pairs)


@settings(max_examples=30, deadline=None)
@given(st.integers(2, 6), st.integers(0, 10 ** 6))
def test_hungarian_permutation_invariance(n, seed):
    """Permuting rows permutes the assignment, same total cost."""
    rng = np.random.default_rng(seed)
    cost = rng.random((n, n)) * 5
    perm = rng.permutation(n)
    t1 = sum(cost[r, c] for r, c in hungarian(cost))
    t2 = sum(cost[perm][r, c] for r, c in hungarian(cost[perm]))
    assert abs(t1 - t2) < 1e-9


@settings(max_examples=40, deadline=None)
@given(st.integers(1, 7), st.integers(1, 7), st.integers(0, 10 ** 6))
def test_hungarian_rect_all_solvers_agree(n, m, seed):
    """Random RECTANGULAR costs: scipy dispatch, the numpy JV reference
    and the batched device kernel must return valid matchings with the
    same minimal total; the host solvers' pair lists must already be
    row-sorted (the transpose path emits them ordered, no re-sort)."""
    rng = np.random.default_rng(seed)
    # multiples of 1/64 in [0, 4): exact in f32, so the kernel's totals
    # (and tie-breaks vs the f64 reference) are exact too
    cost = rng.integers(0, 256, (n, m)).astype(np.float64) / 64.0
    from repro.core.hungarian import _hungarian_np
    got = {"dispatch": hungarian(cost), "np": _hungarian_np(cost),
           "batch": hungarian_batch([cost])[0]}
    totals = {}
    for name, pairs in got.items():
        assert len(pairs) == min(n, m), name
        assert len({r for r, _ in pairs}) == len(pairs), name
        assert len({c for _, c in pairs}) == len(pairs), name
        assert pairs == sorted(pairs), name       # row-ordered output
        totals[name] = sum(cost[r, c] for r, c in pairs)
    assert abs(totals["np"] - totals["dispatch"]) < 1e-9
    assert abs(totals["batch"] - totals["dispatch"]) < 1e-9


@settings(max_examples=25, deadline=None)
@given(st.integers(1, 4), st.integers(2, 7), st.integers(0, 10 ** 6))
def test_hungarian_batch_matches_per_matrix(k, n, seed):
    """One batched dispatch == k independent hungarian() calls (validity
    + totals), mixed square/rect matrices in the same batch."""
    rng = np.random.default_rng(seed)
    costs = [rng.integers(0, 256, (n, max(1, n + d))).astype(np.float64)
             / 64.0 for d in range(-1, k - 1)]
    batched = hungarian_batch(costs)
    for c, pairs in zip(costs, batched):
        single = hungarian(c)
        assert len(pairs) == len(single)
        t_b = sum(c[r, j] for r, j in pairs)
        t_s = sum(c[r, j] for r, j in single)
        assert abs(t_b - t_s) < 1e-9


# ---------------------------------------------------------------------------
# Window grouping invariants
# ---------------------------------------------------------------------------

@settings(max_examples=50, deadline=None)
@given(st.integers(0, 10 ** 6), st.floats(0.02, 0.5))
def test_windows_cover_and_bounded(seed, density):
    rng = np.random.default_rng(seed)
    grid = (rng.random((8, 12)) < density).astype(np.int8)
    tm = detector_time_model((12, 8), 1.0)
    sizes = [(12, 8), (4, 4), (6, 4)]
    ss = SizeSet(sizes, {s: tm(s) for s in sizes})
    windows = group_cells(grid, ss, max_windows=6)
    # 1. all windows inside the grid
    for (x, y, (w, h)) in windows:
        assert 0 <= x and x + w <= 12
        assert 0 <= y and y + h <= 8
        assert (w, h) in sizes
    # 2. coverage
    if grid.sum():
        cover = np.zeros_like(grid)
        for (x, y, (w, h)) in windows:
            cover[y:y + h, x:x + w] = 1
        assert (cover >= grid).all()
        # 3. never slower than the full frame
        assert ss.est(windows) <= ss.times[(12, 8)] + 1e-12
    else:
        assert windows == []


@settings(max_examples=40, deadline=None)
@given(st.integers(0, 10 ** 6), st.floats(0.0, 0.6), st.integers(1, 6))
def test_plan_from_mapped_matches_plan_chunk(seed, density, n_frames):
    """The fused-kernel planning entry (mapped grids + stats rows) must
    be bit-identical to the legacy per-frame ``plan_chunk`` path,
    including the empty-frame and filled-rectangle stat shortcuts."""
    rng = np.random.default_rng(seed)
    grids = []
    for f in range(n_frames):
        if f % 3 == 1:          # force filled-rect frames into the mix
            g = np.zeros((8, 12), np.int8)
            y, x = rng.integers(0, 6), rng.integers(0, 9)
            g[y:y + rng.integers(1, 3), x:x + rng.integers(1, 4)] = 1
        else:
            g = (rng.random((8, 12)) < density).astype(np.int8)
        grids.append(g)
    stats = []
    for g in grids:
        ys, xs = np.nonzero(g)
        if len(ys) == 0:
            stats.append(np.array([0, 8, -1, 12, -1, 0, 0, 0], np.int32))
        else:
            stats.append(np.array([len(ys), ys.min(), ys.max(),
                                   xs.min(), xs.max(), 0, 0, 0],
                                  np.int32))
    tm = detector_time_model((12, 8), 1.0)
    sizes = [(12, 8), (4, 4), (6, 4)]
    ss = SizeSet(sizes, {s: tm(s) for s in sizes})
    ref = plan_chunk(grids, ss, max_windows=6)
    got = plan_from_mapped(grids, stats, ss, max_windows=6,
                           chunk_size=n_frames)
    assert got.windows == ref.windows
    assert got.by_size == ref.by_size


# ---------------------------------------------------------------------------
# Track resampling
# ---------------------------------------------------------------------------

@settings(max_examples=40, deadline=None)
@given(st.integers(2, 30), st.integers(0, 10 ** 6))
def test_resample_preserves_endpoints(n_pts, seed):
    rng = np.random.default_rng(seed)
    pts = np.cumsum(rng.standard_normal((n_pts, 2)) * 0.1, axis=0)
    out = resample_track(pts, 20)
    assert out.shape == (20, 2)
    np.testing.assert_allclose(out[0], pts[0], atol=1e-9)
    np.testing.assert_allclose(out[-1], pts[-1], atol=1e-6)


# ---------------------------------------------------------------------------
# Metrics
# ---------------------------------------------------------------------------

@settings(max_examples=50, deadline=None)
@given(st.lists(st.integers(0, 30), min_size=1, max_size=8))
def test_count_accuracy_bounds_and_identity(gt):
    gt = np.asarray(gt)
    assert count_accuracy(gt, gt) == 1.0
    pred = gt + 1
    a = count_accuracy(pred, gt)
    assert 0.0 <= a <= 1.0


# ---------------------------------------------------------------------------
# HLO shape parsing
# ---------------------------------------------------------------------------

@settings(max_examples=50, deadline=None)
@given(st.lists(st.integers(1, 64), min_size=0, max_size=4),
       st.sampled_from(["f32", "bf16", "s32", "s8"]))
def test_parse_shape_bytes(dims, dtype):
    bytes_per = {"f32": 4, "bf16": 2, "s32": 4, "s8": 1}[dtype]
    text = f"{dtype}[{','.join(map(str, dims))}]"
    total, parsed = _parse_shape(text)
    expect = int(np.prod(dims)) * bytes_per if dims else bytes_per
    assert total == expect
    assert parsed == list(dims)


# ---------------------------------------------------------------------------
# Data pipeline determinism/skippability
# ---------------------------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(st.integers(0, 1000), st.integers(1, 4))
def test_token_pipeline_skippable(step, n_shards):
    from repro.data.tokens import TokenPipeline
    pipe = TokenPipeline(vocab_size=128, batch=8, seq_len=16, seed=3)
    a = pipe.batch_at(step)
    b = pipe.batch_at(step)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    if 8 % n_shards == 0:
        rows = [pipe.batch_at(step, s, n_shards)["tokens"]
                for s in range(n_shards)]
        # shards are disjoint rows of a deterministic global batch
        assert all(r.shape == (8 // n_shards, 16) for r in rows)


# ---------------------------------------------------------------------------
# Fused track-step kernel invariants
# ---------------------------------------------------------------------------

def _track_operands(rng, K, Q, H, e, M):
    """Random slot-contract operands (live tracks / valid dets as
    prefixes, integer te gaps) plus the packed head parameters."""
    def g(*s):
        return rng.standard_normal(s).astype(np.float32)

    np_params = {
        "det_proj/w": g(e + 6, e) * 0.5, "det_proj/b": g(e) * 0.1,
        "gru/wz": g(e + H, H) * 0.5, "gru/wr": g(e + H, H) * 0.5,
        "gru/wh": g(e + H, H) * 0.5,
        "gru/bz": g(H) * 0.1, "gru/br": g(H) * 0.1, "gru/bh": g(H) * 0.1,
        "match/w0": g(H + e + 6, M) * 0.5, "match/b0": g(M) * 0.1,
        "match/w1": g(M, 1) * 0.5, "match/b1": g(1) * 0.1,
    }
    arrs = [np.zeros((K, Q, H), np.float32), np.zeros((K, Q, 4), np.float32),
            np.zeros((K, Q), np.float32), np.zeros((K, Q), np.float32),
            np.zeros((K, Q), np.float32), np.zeros((K, Q, e), np.float32),
            np.zeros((K, Q, 4), np.float32), np.zeros((K, Q), np.float32)]
    for k in range(K):
        T = int(rng.integers(0, Q + 1))
        n = int(rng.integers(0, Q + 1))
        arrs[0][k, :T] = g(T, H) * 0.5
        arrs[1][k, :T] = rng.random((T, 4), np.float32)
        arrs[2][k, :T] = 1.0
        arrs[3][k, :T] = rng.integers(1, 9, T)
        arrs[4][k] = float(rng.integers(0, 9))
        arrs[5][k, :n] = g(n, e) * 0.5
        arrs[6][k, :n] = rng.random((n, 4), np.float32)
        arrs[7][k, :n] = 1.0
    thr = np.full((1, 1), 0.35, np.float32)
    return arrs, thr, np_params


@settings(max_examples=12, deadline=None)
@given(st.integers(1, 3), st.sampled_from([8, 16]),
       st.integers(0, 10 ** 6))
def test_track_step_interpret_matches_ref(K, Q, seed):
    """Pallas interpret == numpy oracle bit-for-bit on random shapes,
    prefix occupancies and threshold-forbidden sentinel patterns."""
    from repro.kernels.track_step import pack_params, track_step_ref
    from repro.kernels.track_step.kernel import track_step_pallas
    from repro.kernels.track_step.ops import LOG1P_TABLE_2D
    import jax.numpy as jnp
    rng = np.random.default_rng(seed)
    H, e, M = 16, 8, 16             # fixed dims keep the jit cache warm
    arrs, thr, np_params = _track_operands(rng, K, Q, H, e, M)
    packed = pack_params(np_params)
    ref = track_step_ref(*arrs, thr, packed, LOG1P_TABLE_2D)
    pal = track_step_pallas(*[jnp.asarray(a) for a in arrs],
                            jnp.asarray(thr), packed, LOG1P_TABLE_2D,
                            interpret=True)
    for r, p in zip(ref, pal):
        np.testing.assert_array_equal(np.asarray(p), r)


@settings(max_examples=8, deadline=None)
@given(st.integers(0, 10 ** 6))
def test_track_step_slot_padding_invariance(seed):
    """Doubling the slot bucket Q (dead rows / invalid columns appended)
    must not change ANY result on the original rows — the property the
    assoc_side-restricted JV solve exists to guarantee (plain f32 JV is
    NOT padding-invariant)."""
    from repro.kernels.track_step import pack_params, track_step_ref
    from repro.kernels.track_step.ops import LOG1P_TABLE_2D
    rng = np.random.default_rng(seed)
    K, Q, H, e, M = 2, 8, 16, 8, 16
    arrs, thr, np_params = _track_operands(rng, K, Q, H, e, M)
    packed = pack_params(np_params)
    ref = track_step_ref(*arrs, thr, packed, LOG1P_TABLE_2D)
    wide = []
    for a in arrs:
        pad = [(0, 0), (0, Q)] + [(0, 0)] * (a.ndim - 2)
        wide.append(np.pad(a, pad))
    ref2 = track_step_ref(*wide, thr, packed, LOG1P_TABLE_2D)
    np.testing.assert_array_equal(ref2[0][:, :Q], ref[0])   # matched
    np.testing.assert_array_equal(ref2[1][:, :Q], ref[1])   # h_upd
    np.testing.assert_array_equal(ref2[2][:, :Q], ref[2])   # h_new


# ---------------------------------------------------------------------------
# DeviceTracker checkpoint round-trip
# ---------------------------------------------------------------------------

@settings(max_examples=6, deadline=None)
@given(st.integers(0, 10 ** 6), st.integers(1, 9))
def test_device_tracker_checkpoint_roundtrip(seed, split):
    """Splitting a DeviceTracker run at any frame through a serialized
    ``TrackerCheckpoint`` (NPZ-array round trip included) yields tracks
    bit-identical to the unsplit run AND to the host tracker."""
    import dataclasses
    from types import SimpleNamespace
    from repro.configs.multiscope import TrackerConfig
    from repro.core.tracker import (DeviceTracker, RecurrentTracker,
                                    init_tracker)
    from repro.stream.checkpoint import TrackerCheckpoint

    cfg = dataclasses.replace(TrackerConfig(), embed_dim=8, rnn_dim=12,
                              match_hidden=12, crop=8, max_tracks=4)
    params = init_tracker(cfg, seed=1)
    rng = np.random.default_rng(seed)
    B = 10
    frames = np.zeros((B, 8, 8, 3), np.float32)
    fids, dets, embeds = [], [], []
    centers = rng.random((3, 2)).astype(np.float32)
    emb_base = rng.standard_normal((3, cfg.embed_dim)).astype(np.float32)
    for k in range(B):
        n = int(rng.integers(0, 4))
        ids = rng.permutation(3)[:n]
        d = np.zeros((n, 5), np.float32)
        em = np.zeros((n, cfg.embed_dim), np.float32)
        for j, oid in enumerate(ids):
            d[j, :2] = centers[oid] + 0.02 * k
            d[j, 2:4] = 0.1
            d[j, 4] = 0.9
            em[j] = emb_base[oid] + 0.01 * k
        fids.append(k)
        dets.append(d)
        embeds.append(em)

    def run(tracker, lo, hi):
        tracker.step_chunk(fids[lo:hi], dets[lo:hi], frames[lo:hi],
                           embeds=embeds[lo:hi])
        return tracker

    host = run(RecurrentTracker(cfg, params), 0, B).result()
    whole = run(DeviceTracker(cfg, params), 0, B).result()
    t2 = run(DeviceTracker(cfg, params), 0, split)
    ckpt = TrackerCheckpoint.capture(t2, split, split)
    ckpt = TrackerCheckpoint.from_arrays(ckpt.to_arrays())
    bank = SimpleNamespace(cfg=SimpleNamespace(tracker=cfg),
                           tracker_params=params)
    t3 = ckpt.restore(bank, None,
                      SimpleNamespace(device_assign=False,
                                      device_tracker=True))
    assert isinstance(t3, DeviceTracker)
    resumed = run(t3, split, B).result()
    assert len(whole) == len(host) == len(resumed)
    for a, b, c in zip(whole, host, resumed):
        np.testing.assert_array_equal(a, b)
        np.testing.assert_array_equal(a, c)
