"""Integration tests for the MultiScope pipeline + serving engine.

These use tiny training budgets — they verify MECHANICS (end-to-end
plumbing, monotone structure), not paper-level accuracy (that is the
benchmark suite's job)."""
import dataclasses

import numpy as np
import pytest

from repro.configs.multiscope import MULTISCOPE_PIPELINE
from repro.core import pipeline as pl
from repro.core.metrics import clip_count_accuracy
from repro.core.proxy import ProxyModel, cells_from_detections
from repro.core.train_models import train_detector
from repro.data.video_synth import make_split


@pytest.fixture(scope="module")
def small_bank():
    cfg = MULTISCOPE_PIPELINE.reduced()
    clips = make_split("caldot1", "train", 2, n_frames=24)
    det, _ = train_detector("ssd-lite", clips,
                            [cfg.detector.resolutions[-1]], steps=60)
    bank = pl.ModelBank(cfg, {"ssd-lite": det, "ssd-deep": det})
    bank.det_times = {(a, r): 0.004 * r[0] * r[1] / (128 * 80)
                      for a in cfg.detector.archs
                      for r in cfg.detector.resolutions}
    return bank, clips


def test_run_clip_full_frame(small_bank):
    bank, clips = small_bank
    cfg = bank.cfg
    params = pl.PipelineParams("ssd-lite", cfg.detector.resolutions[-1],
                               0.4, gap=2, tracker="sort", refine=False)
    r = pl.run_clip(bank, params, clips[0])
    assert r.frames_processed == 12
    assert r.seconds > 0
    assert all(t.shape[1] == 6 for t in r.tracks)


def test_proxy_gating_reduces_windows(small_bank):
    """An all-negative proxy must skip frames; all-positive must fall back
    to full frames."""
    bank, clips = small_bank
    cfg = bank.cfg
    res = cfg.proxy.resolutions[-1]
    proxy = ProxyModel(cfg.proxy.cell, cfg.proxy.base_channels, res)
    bank.proxies = {res: proxy}
    bank.sizes_cells = [pl.det_grid(cfg.detector.resolutions[-1]),
                        (3, 2)]
    bank.ref_grid = pl.det_grid(cfg.detector.resolutions[-1])
    params = pl.PipelineParams(
        "ssd-lite", cfg.detector.resolutions[-1], 0.4, gap=4,
        proxy_res=res, proxy_threshold=0.9999999, tracker="sort",
        refine=False)
    r_high = pl.run_clip(bank, params, clips[0])
    # an untrained proxy with impossible threshold -> everything skipped
    assert r_high.skipped_frames == r_high.frames_processed
    params = dataclasses.replace(params, proxy_threshold=-0.1)
    r_low = pl.run_clip(bank, params, clips[0])
    assert r_low.skipped_frames == 0


def test_map_proxy_grid_maxpool():
    pos = np.zeros((4, 6), np.int8)
    pos[1, 2] = 1
    out = pl.map_proxy_grid(pos, (12, 8))       # (wc, hc)
    assert out.shape == (8, 12)
    assert out.sum() >= 1
    # the positive proxy cell must map onto at least one detector cell
    ys, xs = np.nonzero(out)
    assert all(2 <= y <= 3 for y in ys) and all(4 <= x <= 5 for x in xs)


def test_cells_from_detections_intersection_semantics():
    dets = np.array([[0.5, 0.5, 0.4, 0.4]], np.float32)   # spans cells
    grid = cells_from_detections(dets, 8, 8)
    assert grid.sum() >= 9                                # 3x3 at least


def test_proxy_threshold_sweep_and_calibration():
    """The paper's threshold sweep over cached score grids: recall and
    positive rate fall monotonically with the threshold, and
    calibration picks the LARGEST (sparsest) threshold meeting the
    recall target."""
    from repro.core.proxy import (calibrate_threshold, sweep_candidates,
                                  threshold_sweep)
    rng = np.random.default_rng(0)
    score_grids, label_grids = [], []
    for _ in range(8):
        lab = (rng.random((6, 8)) < 0.2).astype(np.int8)
        # a decent proxy: labelled cells score visibly higher
        s = rng.random((6, 8)) * 0.4 + lab * 0.5
        score_grids.append(s.astype(np.float32))
        label_grids.append(lab)
    ths = [0.1, 0.3, 0.45, 0.6, 0.95]
    sweep = threshold_sweep(score_grids, label_grids, ths)
    recalls = [r for _, r, _ in sweep]
    rates = [p for _, _, p in sweep]
    assert recalls == sorted(recalls, reverse=True)
    assert rates == sorted(rates, reverse=True)
    assert recalls[0] == 1.0 and recalls[-1] < 0.5
    th = calibrate_threshold(score_grids, label_grids, ths,
                             min_recall=0.95)
    ok = [t for t, r, _ in threshold_sweep(
        score_grids, label_grids,
        sweep_candidates(score_grids, ths)) if r >= 0.95]
    assert th == max(ok)
    # unreachable target falls back to the best-recall candidate
    lo = calibrate_threshold(score_grids, label_grids, [0.99],
                             min_recall=0.999)
    assert lo <= th


def test_serving_engine_greedy_deterministic():
    import jax.numpy as jnp
    from repro.configs import get_config
    from repro.models.model import build_model
    from repro.serve import ServeEngine
    cfg = get_config("qwen2-0.5b").reduced()
    m = build_model(cfg)
    params = m.init_params(0)
    eng = ServeEngine(m, params, max_len=48)
    a = eng.generate([[1, 2, 3], [7, 8]], max_new_tokens=5)
    b = eng.generate([[1, 2, 3], [7, 8]], max_new_tokens=5)
    assert a == b
    assert len(a[0]) == 8 and len(a[1]) == 7
