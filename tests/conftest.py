"""Shared fixtures.  NOTE: XLA_FLAGS is deliberately NOT set here — smoke
tests and benchmarks must see the real single CPU device; only the
dry-run launcher forces 512 placeholder devices."""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np  # noqa: E402
import pytest  # noqa: E402


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)
