"""Shared fixtures.  NOTE: XLA_FLAGS is deliberately NOT set here — smoke
tests and benchmarks must see the real single CPU device; only the
dry-run launcher forces 512 placeholder devices."""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np  # noqa: E402
import pytest  # noqa: E402


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


@pytest.fixture(scope="session")
def qsys(tmp_path_factory):
    """One trained bank + params + 3 ingested clips + warm TrackStore,
    shared by tests/test_query.py and tests/test_query_index.py (the
    detector training dominates the cost, so build it once a session)."""
    import repro.core.pipeline as pl
    from repro.configs.multiscope import MULTISCOPE_PIPELINE
    from repro.core.proxy import ProxyModel
    from repro.core.tracker import init_tracker
    from repro.core.train_models import train_detector
    from repro.data.video_synth import make_split
    from repro.query import TrackStore

    cfg = MULTISCOPE_PIPELINE.reduced()
    clips = make_split("caldot1", "test", 3, n_frames=24)
    det, _ = train_detector("ssd-lite", clips[:2],
                            [cfg.detector.resolutions[-1]], steps=60)
    bank = pl.ModelBank(cfg, {"ssd-lite": det, "ssd-deep": det})
    res = cfg.proxy.resolutions[-1]
    proxy = ProxyModel(cfg.proxy.cell, cfg.proxy.base_channels, res)
    bank.proxies = {res: proxy}
    bank.sizes_cells = [pl.det_grid(cfg.detector.resolutions[-1]),
                        (3, 2), (5, 3)]
    bank.ref_grid = pl.det_grid(cfg.detector.resolutions[-1])
    bank.tracker_params = init_tracker(cfg.tracker)
    W, H = cfg.detector.resolutions[-1]
    frame, _ = pl.render_frame(clips[0], 0, W, H)
    s, _ = proxy.scores(pl._downsample(frame, res))
    params = pl.PipelineParams(
        "ssd-lite", cfg.detector.resolutions[-1], 0.4, gap=1,
        proxy_res=res, proxy_threshold=float(np.quantile(s, 0.85)),
        tracker="sort", refine=False)
    root = str(tmp_path_factory.mktemp("trackstore"))
    store = TrackStore(root, bank, params)
    store.ingest(clips)
    return bank, params, clips, store, root
