"""Behaviour tests for the paper's core: windows, hungarian, refinement,
metrics, tracker pieces, synthetic data determinism."""
import itertools

import numpy as np
import pytest

from repro.configs.multiscope import MULTISCOPE_PIPELINE, RefineConfig
from repro.core.detector import iou_matrix, make_targets, nms
from repro.core.hungarian import hungarian
from repro.core.metrics import (classify_track, count_accuracy, mota,
                                pattern_counts)
from repro.core.refine import TrackRefiner, dbscan_tracks, resample_track
from repro.core.sort import SortTracker
from repro.core.windows import (SizeSet, connected_components,
                                detector_time_model, group_cells,
                                select_window_sizes)
from repro.data.video_synth import DATASETS, make_clip


# ---------------------------------------------------------------------------
# Hungarian
# ---------------------------------------------------------------------------

def _brute_min(cost):
    n, m = cost.shape
    k = min(n, m)
    best = np.inf
    for cols in itertools.permutations(range(m), k):
        for rows in itertools.combinations(range(n), k):
            best = min(best, sum(cost[r, c]
                                 for r, c in zip(rows, cols)))
    return best


@pytest.mark.parametrize("solver", ["dispatch", "numpy"])
def test_hungarian_optimal(solver):
    """Covers both the scipy dispatch AND the numpy JV fallback — the
    fallback is the only path on scipy-free installs and would otherwise
    never run in CI."""
    from repro.core.hungarian import _hungarian_np
    solve = hungarian if solver == "dispatch" else _hungarian_np
    rng = np.random.default_rng(0)
    for _ in range(60):
        n, m = rng.integers(1, 6, 2)
        cost = rng.random((n, m)) * 10
        pairs = solve(cost)
        tot = sum(cost[r, c] for r, c in pairs)
        assert abs(tot - _brute_min(cost)) < 1e-9
        # a valid matching: each row/col used at most once
        assert len({r for r, _ in pairs}) == len(pairs)
        assert len({c for _, c in pairs}) == len(pairs)


# ---------------------------------------------------------------------------
# Windows
# ---------------------------------------------------------------------------

def _sizeset(full=(12, 8), extra=((4, 4), (6, 4))):
    sizes = [full] + list(extra)
    tm = detector_time_model(full, 1.0)
    return SizeSet(sizes, {s: tm(s) for s in sizes})


def test_group_cells_covers_all_positives():
    rng = np.random.default_rng(1)
    ss = _sizeset()
    for _ in range(40):
        grid = (rng.random((8, 12)) < 0.15).astype(np.int8)
        windows = group_cells(grid, ss, max_windows=8)
        if grid.sum() == 0:
            assert windows == []
            continue
        cover = np.zeros_like(grid)
        for (x, y, (w, h)) in windows:
            cover[y:y + h, x:x + w] = 1
        assert (cover >= grid).all(), "window set must cover positives"


def test_group_cells_never_slower_than_full_frame():
    rng = np.random.default_rng(2)
    ss = _sizeset()
    for _ in range(40):
        grid = (rng.random((8, 12)) < 0.3).astype(np.int8)
        windows = group_cells(grid, ss, max_windows=4)
        if grid.sum():
            assert ss.est(windows) <= ss.times[ss.full] + 1e-12


def test_empty_grid_skips_frame():
    ss = _sizeset()
    assert group_cells(np.zeros((8, 12), np.int8), ss) == []


def test_connected_components():
    grid = np.zeros((5, 5), np.int8)
    grid[0, 0] = grid[0, 1] = 1          # one component
    grid[4, 4] = 1                       # another
    comps = connected_components(grid)
    assert sorted(len(c) for c in comps) == [1, 2]


def test_select_window_sizes_includes_full_and_helps():
    rng = np.random.default_rng(3)
    grids = []
    for _ in range(20):
        g = np.zeros((8, 12), np.int8)
        # objects cluster in a small area (windows should pay off)
        y, x = rng.integers(0, 5), rng.integers(0, 9)
        g[y:y + 2, x:x + 3] = 1
        grids.append(g)
    tm = detector_time_model((12, 8), 1.0)
    S = select_window_sizes(grids, (12, 8), 3, tm)
    assert S[0] == (12, 8)
    assert len(S) >= 2                  # found at least one useful size
    ss = SizeSet(S, {s: tm(s) for s in S})
    est = sum(ss.est(group_cells(g, ss)) for g in grids)
    assert est < 20 * tm((12, 8)) * 0.8   # >20% faster than full frames


# ---------------------------------------------------------------------------
# SORT
# ---------------------------------------------------------------------------

def test_sort_tracks_linear_motion():
    t = SortTracker()
    for f in range(10):
        dets = np.array([[0.1 + 0.05 * f, 0.5, 0.1, 0.1, 0.9],
                         [0.9 - 0.05 * f, 0.3, 0.1, 0.1, 0.9]],
                        np.float32)
        t.step(f, dets)
    tracks = t.result()
    assert len(tracks) == 2
    assert all(len(tr) == 10 for tr in tracks)


# ---------------------------------------------------------------------------
# Refinement
# ---------------------------------------------------------------------------

def test_refiner_extends_partial_track():
    rng = np.random.default_rng(4)
    train_tracks = []
    for i in range(12):
        xs = np.linspace(0.0, 1.0, 30)
        ys = 0.5 + 0.01 * rng.standard_normal(30)
        tr = np.zeros((30, 6), np.float32)
        tr[:, 0] = np.arange(30)
        tr[:, 1] = xs
        tr[:, 2] = ys
        train_tracks.append(tr)
    cfg = RefineConfig(dbscan_eps=20.0, grid_cell=32)
    refiner = TrackRefiner(cfg, train_tracks, frame_scale=1.0 / 192)
    partial = np.zeros((5, 6), np.float32)
    partial[:, 0] = np.arange(5)
    partial[:, 1] = np.linspace(0.4, 0.6, 5)      # middle section only
    partial[:, 2] = 0.5
    out = refiner.refine(partial)
    assert len(out) == 7                          # start + end appended
    assert out[0, 1] < 0.15 and out[-1, 1] > 0.85


def test_resample_track_matches_scan_loop():
    """The searchsorted-vectorized resample must be bit-identical to the
    original per-target scan loop, zero-length segments included."""
    def reference(boxes, n):
        pts = boxes[:, :2].astype(np.float64)
        if len(pts) == 1:
            return np.repeat(pts, n, axis=0)
        seg = np.linalg.norm(np.diff(pts, axis=0), axis=1)
        cum = np.concatenate([[0.0], np.cumsum(seg)])
        total = cum[-1]
        if total <= 0:
            return np.repeat(pts[:1], n, axis=0)
        targets = np.linspace(0.0, total, n)
        out = np.empty((n, 2))
        j = 0
        for i, d in enumerate(targets):
            while j < len(seg) - 1 and cum[j + 1] < d:
                j += 1
            u = 0.0 if seg[j] == 0 else (d - cum[j]) / seg[j]
            out[i] = pts[j] * (1 - u) + pts[j + 1] * u
        return out

    rng = np.random.default_rng(7)
    for _ in range(200):
        m = int(rng.integers(1, 16))
        pts = rng.random((m, 4)).astype(np.float32)
        if m > 3 and rng.random() < 0.4:        # repeated points
            pts[1] = pts[0]
            pts[m // 2] = pts[m // 2 - 1]
        if rng.random() < 0.05:                 # fully degenerate
            pts[:] = pts[0]
        n = int(rng.integers(2, 12))
        np.testing.assert_array_equal(resample_track(pts, n),
                                      reference(pts, n))


def test_dbscan_merges_redundant_paths():
    paths = [resample_track(
        np.stack([np.linspace(0, 1, 10), np.full(10, 0.5)], 1), 20)
        for _ in range(5)]
    paths += [resample_track(
        np.stack([np.full(10, 0.5), np.linspace(0, 1, 10)], 1), 20)]
    clusters = dbscan_tracks(paths, eps=0.05, min_pts=2)
    sizes = sorted(len(c) for c in clusters)
    assert sizes == [1, 5]


# ---------------------------------------------------------------------------
# Metrics
# ---------------------------------------------------------------------------

def test_count_accuracy_perfect_and_floor():
    assert count_accuracy(np.array([3, 2]), np.array([3, 2])) == 1.0
    assert count_accuracy(np.array([9, 0]), np.array([3, 2])) < 0.5


def test_mota_perfect_on_ground_truth():
    clip = make_clip("caldot1", "test", 0)
    tracks = [np.concatenate(
        [t.frames[:, None].astype(np.float32), t.boxes,
         np.full((len(t.frames), 1), t.track_id, np.float32)], axis=1)
        for t in clip.tracks]
    assert mota(tracks, clip) == pytest.approx(1.0)
    assert count_accuracy(pattern_counts(tracks, clip.profile),
                          clip.pattern_counts()) == pytest.approx(1.0)


# ---------------------------------------------------------------------------
# Synthetic data
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", DATASETS)
def test_clip_determinism_and_gt(name):
    a = make_clip(name, "train", 1)
    b = make_clip(name, "train", 1)
    assert len(a.tracks) == len(b.tracks)
    np.testing.assert_array_equal(a.pattern_counts(), b.pattern_counts())
    fa = a.render(3, 96, 64)
    fb = b.render(3, 96, 64)
    np.testing.assert_array_equal(fa, fb)
    assert fa.shape == (64, 96, 3)


def test_detector_targets_roundtrip():
    boxes = [np.array([[0.5, 0.5, 0.2, 0.2]], np.float32)]
    obj, box = make_targets(boxes, 8, 8)
    assert obj.sum() == 1
    i, j = np.argwhere(obj[0])[0]
    assert (i, j) == (4, 4)
