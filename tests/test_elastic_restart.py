"""End-to-end elastic restart: train on mesh A, checkpoint, restore onto
mesh B with different axis sizes, continue — losses match a no-failure
run exactly (single-device CPU meshes of different logical shapes)."""
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh

from repro.configs import get_config
from repro.data.tokens import TokenPipeline
from repro.distributed.checkpoint import Checkpointer
from repro.distributed.sharding import LogicalRules, tree_shardings
from repro.models.model import build_model
from repro.optim import adamw
from repro.train import build_train_step


def _mesh(shape, names):
    return Mesh(np.array(jax.devices()[:1]).reshape(shape), names)


def test_train_checkpoint_remesh_continue():
    cfg = get_config("qwen2-0.5b").reduced()
    model = build_model(cfg)
    opt = adamw(lr=1e-3)
    ts = build_train_step(model, opt)
    pipe = TokenPipeline(cfg.vocab_size, batch=4, seq_len=16, seed=1)
    step_jit = jax.jit(lambda p, s, b: ts(p, s, b))

    def run(n_from, params, state):
        losses = []
        for i in range(n_from, n_from + 3):
            b = {k: jnp.asarray(v) for k, v in pipe.batch_at(i).items()}
            params, state, m = step_jit(params, state, b)
            losses.append(float(m["loss"]))
        return params, state, losses

    # reference: 6 uninterrupted steps
    p0 = model.init_params(0)
    s0 = opt.init(p0)
    p, s, l_a = run(0, p0, s0)
    _, _, l_ref = run(3, p, s)

    # interrupted: checkpoint at step 3, restore onto a DIFFERENT mesh
    with tempfile.TemporaryDirectory() as d:
        ck = Checkpointer(d)
        ck.save(3, {"params": p, "opt": s})
        mesh_b = _mesh((1, 1), ("data", "model"))
        rules = LogicalRules(mesh_b)
        shardings = {
            "params": tree_shardings(rules, model.param_shapes(),
                                     model.param_axes()),
            "opt": tree_shardings(
                rules, jax.eval_shape(opt.init, model.param_shapes()),
                opt.state_axes(model.param_axes())),
        }
        restored, man = ck.restore({"params": p, "opt": s},
                                   shardings=shardings)
    _, _, l_b = run(3, restored["params"], restored["opt"])
    np.testing.assert_allclose(l_b, l_ref, rtol=1e-5)


def test_compressed_psum_shard_map_single_device():
    """compressed_psum semantics under shard_map on a trivial axis."""
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P
    from repro.distributed.compression import compressed_psum
    mesh = _mesh((1,), ("data",))
    x = jnp.linspace(-1, 1, 64).reshape(8, 8)

    f = shard_map(lambda v: compressed_psum(v, "data"), mesh,
                  in_specs=P(), out_specs=P())
    out = f(x)
    # single participant: quantize/dequantize roundtrip only
    assert float(jnp.abs(out - x).max()) < 0.02
