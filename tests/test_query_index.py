"""Index + eviction + cross-dataset serving tests (PR 4).

Covers the new layers of the query subsystem:

  * **differential correctness** — the two-phase indexed plan returns
    BIT-IDENTICAL answers to the full row scan and to the naive inline
    ``ref.reference_query`` loop, across a grid of
    region × time × min_len × count × limit × aggregate shapes;
  * **index pruning** — summaries skip whole clips (``skipped_clips``
    proves it) and histograms answer indexed predicates without
    touching rows (``indexed_clips``), including on a real
    executor-extracted store;
  * **eviction** — ``StoreBudget`` LRU/TTL eviction keeps the store
    under budget, evicted clips stay summarized (skippable without
    re-ingest) and re-ingest bit-identically on the next touch;
  * **bugfix regressions** — the get/has θ-swap race, the prune crash
    on nested version content, and the even-history median bug.
"""
import dataclasses
import itertools
import json
import os
import shutil
import threading
import time

import numpy as np
import pytest

from repro.core.pipeline import PipelineParams, RunResult
from repro.query import (MIN_LEN_BUCKETS, CountAtLeast, Limit,
                         PackedTracks, Query, QueryService, Region,
                         StoreBudget, TimeRange, TrackFilter,
                         TrackStore, compile_query, theta_fingerprint)
from repro.query.ref import reference_limit_scan, reference_query
from repro.query.store import clip_key


# ---------------------------------------------------------------------------
# Fake clips + bank-less stores (no models: materialize directly)
# ---------------------------------------------------------------------------

class _Profile:
    def __init__(self, name: str, fps: int = 8):
        self.name, self.fps = name, fps


class _Clip:
    def __init__(self, profile, clip_id: int, n_frames: int,
                 split: str = "test"):
        self.profile, self.clip_id = profile, clip_id
        self.n_frames, self.split = n_frames, split


def _params(**kw) -> PipelineParams:
    base = dict(det_arch="ssd-lite", det_res=(64, 48), det_conf=0.4,
                gap=1, proxy_res=None, tracker="sort", refine=False)
    base.update(kw)
    return PipelineParams(**base)


def _result(tracks, n_frames) -> RunResult:
    return RunResult(tracks=list(tracks), seconds=0.01,
                     frames_processed=n_frames, detector_windows=0,
                     full_frames=0, skipped_frames=0)


def _make_tracks(rng, n_tracks, n_frames, center, spread=0.08,
                 max_len=7):
    tracks = []
    for t in range(n_tracks):
        ln = int(rng.integers(1, max_len + 1))
        start = int(rng.integers(0, max(1, n_frames - ln + 1)))
        frames = np.arange(start, start + ln, dtype=np.float32)
        cx = np.clip(center[0] + rng.normal(0, spread, ln), 0, 1)
        cy = np.clip(center[1] + rng.normal(0, spread, ln), 0, 1)
        size = np.full(ln, 0.05, np.float32)
        tracks.append(np.stack(
            [frames, cx.astype(np.float32), cy.astype(np.float32),
             size, size, np.full(ln, t, np.float32)], axis=1))
    return tracks


def _fleet(seed=0, dataset="fake"):
    """A varied set of clips: clustered, empty, spread, early-only."""
    rng = np.random.default_rng(seed)
    prof = _Profile(dataset)
    specs = [
        (40, _make_tracks(rng, 6, 40, (0.25, 0.25))),   # lower-left
        (40, _make_tracks(rng, 5, 40, (0.75, 0.75))),   # upper-right
        (40, []),                                        # empty clip
        (48, _make_tracks(rng, 8, 48, (0.5, 0.5), spread=0.3)),
        (40, [t for t in _make_tracks(rng, 4, 9, (0.4, 0.6))]),
    ]
    clips = [_Clip(prof, i, nf) for i, (nf, _) in enumerate(specs)]
    all_tracks = [trs for _, trs in specs]
    return clips, all_tracks


def _fake_store(root, clips, all_tracks, params=None,
                budget=None) -> TrackStore:
    store = TrackStore(str(root), None, params or _params(),
                       budget=budget)
    for clip, tracks in zip(clips, all_tracks):
        store.materialize(clip, _result(tracks, clip.n_frames))
    return store


def _entries(clips, all_tracks):
    return [(c, PackedTracks.pack(t, c))
            for c, t in zip(clips, all_tracks)]


def _query(region, time_range, min_len, min_count, limit=None,
           aggregate="frames"):
    where = [TrackFilter(min_len=min_len), CountAtLeast(min_count)]
    if region is not None:
        where.append(Region(*region))
    if time_range is not None:
        where.append(TimeRange(*time_range))
    return Query(tuple(where),
                 None if limit is None else Limit(*limit), aggregate)


# ---------------------------------------------------------------------------
# Differential: indexed == full scan == inline reference, all shapes
# ---------------------------------------------------------------------------

REGIONS = (None, (0.0, 0.0, 1.0, 1.0), (0.0, 0.0, 0.5, 0.5),
           (0.6, 0.6, 1.0, 1.0), (0.45, 0.0, 0.55, 1.0),
           (0.9, 0.02, 0.97, 0.08))
TIMES = (None, (0, None), (5, 20), (30, None), (0, 4))
MIN_LENS = (1, 2, 3, 4)          # 4 is off-bucket: exercises fallback
COUNTS = (1, 2, 4)


def test_differential_grid_all_query_shapes():
    clips, all_tracks = _fleet()
    entries = _entries(clips, all_tracks)
    fps = [c.profile.fps for c in clips]
    skipped = indexed = 0
    shapes = 0
    for region, trange, mlen, mcount in itertools.product(
            REGIONS, TIMES, MIN_LENS, COUNTS):
        for limit, agg in ((None, "count"), (None, "frames"),
                           (None, "duration"), (None, "tracks"),
                           ((5, 0), "frames"), ((3, 3), "frames")):
            q = _query(region, trange, mlen, mcount, limit, agg)
            plan = compile_query(q)
            a = plan.run(entries, use_index=True)
            b = plan.run(entries, use_index=False)
            assert a.frames == b.frames, plan.describe()
            assert a.aggregates == b.aggregates, plan.describe()
            ref = reference_query(
                all_tracks, fps, region=region, time_range=trange,
                min_len=mlen, min_count=mcount, limit=limit,
                aggregate=agg)
            assert a.frames == ref["frames"], plan.describe()
            assert a.aggregates == ref["aggregates"], plan.describe()
            skipped += a.skipped_clips
            indexed += a.indexed_clips
            shapes += 1
    # both index phases must actually fire somewhere in the grid
    assert skipped > 0 and indexed > 0
    assert shapes == len(REGIONS) * len(TIMES) * len(MIN_LENS) \
        * len(COUNTS) * 6


def test_disjoint_region_fold_skips_everything():
    clips, all_tracks = _fleet()
    entries = _entries(clips, all_tracks)
    q = Query((Region(0.0, 0.0, 0.2, 0.2), Region(0.8, 0.8, 1.0, 1.0),
               CountAtLeast(1)), aggregate="count")
    res = compile_query(q).run(entries)
    assert res.aggregates["count"] == 0
    assert res.skipped_clips == len(entries) and res.scanned_clips == 0


def test_selective_region_skips_clips_bit_identically():
    clips, all_tracks = _fleet()
    entries = _entries(clips, all_tracks)
    # lower-left box: the upper-right cluster + empty clip must skip
    q = _query((0.0, 0.0, 0.35, 0.35), None, 2, 1, aggregate="count")
    plan = compile_query(q)
    a = plan.run(entries, use_index=True)
    b = plan.run(entries, use_index=False)
    assert a.aggregates == b.aggregates
    assert a.skipped_clips >= 2
    assert a.scanned_clips + a.skipped_clips == len(entries)
    assert b.skipped_clips == 0 and b.scanned_clips == len(entries)


def test_histogram_answers_indexed_predicates():
    clips, all_tracks = _fleet()
    entries = _entries(clips, all_tracks)
    for mlen in MIN_LEN_BUCKETS:
        for trange in (None, (5, 20)):
            q = _query(None, trange, mlen, 1, aggregate="count")
            res = compile_query(q).run(entries)
            # every clip the plan actually scanned came from the hist
            assert res.indexed_clips == res.scanned_clips > 0
    # off-bucket min_len and full-coverage region both fall back
    res = compile_query(_query(None, None, 4, 1, aggregate="count")) \
        .run(entries)
    assert res.indexed_clips == 0
    # a region CONTAINING every track bbox is a provable no-op, so the
    # histogram still answers
    res = compile_query(
        _query((0.0, 0.0, 1.0, 1.0), None, 2, 1, aggregate="count")) \
        .run(entries)
    assert res.indexed_clips == res.scanned_clips > 0
    # ...but a region that actually filters SOME clip's rows forces the
    # scan for that clip (containment is decided per clip, so others
    # whose bbox fits inside the region may still go indexed)
    res = compile_query(
        _query((0.2, 0.2, 0.8, 0.8), None, 2, 1, aggregate="count")) \
        .run(entries)
    assert res.indexed_clips < res.scanned_clips


def test_limit_early_exit_still_counts_skips():
    clips, all_tracks = _fleet()
    entries = _entries(clips, all_tracks)
    q = _query(None, None, 1, 1, limit=(100, 0))
    plan = compile_query(q)
    a = plan.run(entries, use_index=True)
    b = plan.run(entries, use_index=False)
    assert a.frames == b.frames
    assert a.skipped_clips >= 1          # the empty clip


# ---------------------------------------------------------------------------
# Index persistence: NPZ arrays + index.json summaries
# ---------------------------------------------------------------------------

def test_index_persisted_in_npz_and_json(tmp_path):
    clips, all_tracks = _fleet()
    store = _fake_store(tmp_path / "s", clips, all_tracks)
    vdir = os.path.join(str(tmp_path / "s"), "fake", store.fingerprint)
    with np.load(store._clip_path(clip_key(clips[0]))) as z:
        assert "hist" in z.files and "track_bbox" in z.files
        assert z["hist"].shape[0] == len(MIN_LEN_BUCKETS)
    with open(os.path.join(vdir, "index.json")) as f:
        doc = json.load(f)
    assert doc["buckets"] == list(MIN_LEN_BUCKETS)
    assert len(doc["clips"]) == len(clips)
    # the empty clip serializes empty bboxes as null
    empty = doc["clips"]["test_2_40"]
    assert empty["summary"]["n_rows"] == 0
    assert empty["summary"]["bbox"] == [None] * len(MIN_LEN_BUCKETS)

    # a FRESH store over the same root serves summaries from
    # index.json without touching a single NPZ
    fresh = TrackStore(str(tmp_path / "s"), None, _params())
    for clip, tracks in zip(clips, all_tracks):
        s = fresh.summary(clip)
        assert s is not None
        assert s == PackedTracks.pack(tracks, clip).summary
        assert clip_key(clip) not in fresh._index    # nothing loaded


def test_loaded_clip_roundtrips_index_arrays(tmp_path):
    clips, all_tracks = _fleet()
    store = _fake_store(tmp_path / "s", clips, all_tracks)
    fresh = TrackStore(str(tmp_path / "s"), None, _params())
    for clip, tracks in zip(clips, all_tracks):
        a = fresh.get(clip)
        b = PackedTracks.pack(tracks, clip)
        np.testing.assert_array_equal(a.hist, b.hist)
        np.testing.assert_array_equal(a.track_bbox, b.track_bbox)


# ---------------------------------------------------------------------------
# Eviction: LRU / TTL budgets, metadata-preserving
# ---------------------------------------------------------------------------

def test_lru_eviction_respects_recency(tmp_path):
    clips, all_tracks = _fleet()
    store = _fake_store(tmp_path / "s", clips, all_tracks)
    sizes = {clip_key(c): store._entries[clip_key(c)]["bytes"]
             for c in clips}
    store.get(clips[0])                  # clip 0 becomes most recent
    keep = sizes[clip_key(clips[0])] + sizes[clip_key(clips[-1])]
    evicted = store.set_budget(StoreBudget(max_bytes=keep))
    assert evicted == len(clips) - 2
    assert store.disk_bytes() <= keep
    assert store.has(clips[0]) and store.has(clips[-1])
    for c in clips[1:-1]:
        assert not store.has(c)
        assert store.summary(c) is not None      # summary survives
        assert store.get(c) is None


def test_lru_freshness_survives_index_json_reload(tmp_path):
    """A get() on a FRESH store registers an entry before the dataset's
    bulk index.json load; the later load must not clobber its
    last_used, or the most-recently-used clip gets evicted first."""
    clips, all_tracks = _fleet()
    _fake_store(tmp_path / "s", clips, all_tracks)
    store = TrackStore(str(tmp_path / "s"), None, _params())
    store.get(clips[0])                  # registered pre-bulk-load
    sizes = {clip_key(c): os.path.getsize(store._clip_path(clip_key(c)))
             for c in clips}
    keep = sizes[clip_key(clips[0])] + min(
        sizes[clip_key(c)] for c in clips[1:])
    store.set_budget(StoreBudget(max_bytes=keep))   # bulk-loads the rest
    assert store.has(clips[0])           # the touched clip survived


def test_ttl_eviction(tmp_path):
    clips, all_tracks = _fleet()
    store = _fake_store(tmp_path / "s", clips, all_tracks)
    time.sleep(0.05)
    evicted = store.set_budget(StoreBudget(ttl_seconds=0.01))
    assert evicted == len(clips)
    assert store.disk_bytes() == 0
    assert all(store.summary(c) is not None for c in clips)


def test_evicted_clip_skipped_without_reingest(tmp_path):
    """A query whose predicate provably misses an evicted clip must be
    answered WITHOUT re-ingesting it (the store has no bank here, so
    any ingest attempt would raise)."""
    clips, all_tracks = _fleet()
    store = _fake_store(tmp_path / "s", clips, all_tracks)
    service = QueryService(store)
    q = _query((0.55, 0.55, 1.0, 1.0), None, 2, 1, aggregate="count")
    before = service.query(q, clips).aggregates
    # evict the lower-left cluster (clip 0): the query skips it anyway
    with store._lock:
        store._evict(clip_key(clips[0]))
        store._flush_index("fake")
    res = service.query(q, clips)
    assert res.aggregates == before
    assert res.stats.ingested_clips == 0
    assert res.skipped_clips >= 1
    # a query that DOES need the evicted clip fails loudly (no bank)
    need = _query((0.0, 0.0, 1.0, 1.0), None, 1, 1, aggregate="count")
    with pytest.raises(RuntimeError):
        service.query(need, clips)


def test_eviction_then_requery_matches(qsys, tmp_path):
    """Acceptance: evict under a byte budget, re-query, get the same
    answers back through transparent re-ingest."""
    bank, params, clips, _, root = qsys
    new_root = str(tmp_path / "copy")
    shutil.copytree(root, new_root)
    store = TrackStore(new_root, bank, params)
    service = QueryService(store)
    q = Query.count_frames(min_count=1)
    ql = Query.limit_frames(want=6, min_spacing=2)
    before_count = service.query(q, clips).aggregates
    before_frames = service.query(ql, clips).frames
    total = store.disk_bytes()
    evicted = store.set_budget(StoreBudget(max_bytes=total - 1))
    assert evicted >= 1
    assert store.disk_bytes() <= total - 1
    det = bank.detectors[params.det_arch]
    calls0 = det.dispatches
    after_count = service.query(q, clips)
    after_frames = service.query(ql, clips).frames
    assert after_count.aggregates == before_count
    assert after_frames == before_frames
    assert after_count.stats.ingested_clips == evicted
    assert det.dispatches > calls0       # re-ingest really ran models


def test_ingest_report_eviction_counters(qsys, tmp_path):
    bank, params, clips, _, root = qsys
    new_root = str(tmp_path / "copy")
    shutil.copytree(root, new_root)
    keep_two = TrackStore(new_root, bank, params).disk_bytes() * 2 // 3
    store = TrackStore(new_root, bank, params,
                       budget=StoreBudget(max_bytes=keep_two))
    # warm ingest of a subset: budget enforcement runs, batch protected
    report = store.ingest(clips[:2])
    assert report.ingested == 0 and report.cached == 2
    assert report.evicted >= 1 and report.evicted_bytes > 0
    assert report.store_bytes <= keep_two
    assert all(store.has(c) for c in clips[:2])     # batch survived


def test_prune_after_eviction_leaves_only_current(tmp_path):
    clips, all_tracks = _fleet()
    root = tmp_path / "s"
    store = _fake_store(root, clips, all_tracks)
    # a stale version with NESTED content (the old unlink+rmdir prune
    # crashed on exactly this) ...
    stale = os.path.join(str(root), "fake", "deadbeefdeadbeef")
    os.makedirs(os.path.join(stale, "sub", "dir"))
    with open(os.path.join(stale, "sub", "dir", "x.npz"), "w") as f:
        f.write("stale")
    # ... plus an eviction in the live version
    with store._lock:
        store._evict(clip_key(clips[0]))
        store._flush_index("fake")
    removed = store.prune()
    assert removed == ["deadbeefdeadbeef"]
    left = os.listdir(os.path.join(str(root), "fake"))
    assert left == [store.fingerprint]
    vdir = os.path.join(str(root), "fake", store.fingerprint)
    names = sorted(os.listdir(vdir))
    assert "index.json" in names and "meta.json" in names
    assert f"test_{clips[0].clip_id}_40.npz" not in names


def test_prune_missing_root(tmp_path):
    store = TrackStore(str(tmp_path / "never_created"), None, _params())
    assert store.prune() == []


# ---------------------------------------------------------------------------
# Bugfix regressions: θ-swap race, latency report
# ---------------------------------------------------------------------------

def test_get_theta_swap_race(tmp_path):
    """set_params racing get() must not cache (or report) the old θ's
    clip under the new version's index."""
    clips, all_tracks = _fleet()
    _fake_store(tmp_path / "s", clips[:1], all_tracks[:1])
    store = TrackStore(str(tmp_path / "s"), None, _params())
    inside, resume = threading.Event(), threading.Event()
    orig = store._read_clip

    def slow_read(path):
        inside.set()
        assert resume.wait(5)
        return orig(path)

    store._read_clip = slow_read
    out = []
    th = threading.Thread(
        target=lambda: out.append(store.get(clips[0])))
    th.start()
    assert inside.wait(5)                # loader is mid-read
    changed = _params(det_conf=0.9)
    store.set_params(changed)            # θ swaps under the loader
    resume.set()
    th.join(5)
    assert out == [None]                 # stale-θ read not served
    assert clip_key(clips[0]) not in store._index
    assert not store.has(clips[0])       # new θ: cold, not warm
    store.set_params(_params())          # back to the old θ
    assert store.get(clips[0]) is not None


def test_has_snapshots_fingerprint(tmp_path):
    """has() must evaluate existence against ONE fingerprint, not mix
    the index check of one θ with the path of another."""
    clips, all_tracks = _fleet()
    store = _fake_store(tmp_path / "s", clips[:1], all_tracks[:1])
    fp_a = store.fingerprint
    store.set_params(_params(det_conf=0.9))
    assert not store.has(clips[0])
    store.set_params(_params())
    assert store.fingerprint == fp_a and store.has(clips[0])


def test_latency_report_median_and_p95(tmp_path):
    from repro.query.service import QueryStats
    clips, all_tracks = _fleet()
    store = _fake_store(tmp_path / "s", clips[:1], all_tracks[:1])
    service = QueryService(store)
    for v in (0.4, 0.1, 0.2, 0.3):       # even-length history
        service._history.append(QueryStats(scan_seconds=v))
    rep = service.latency_report()
    assert rep["queries"] == 4
    # interpolated median, NOT the upper middle element (0.3)
    assert rep["scan_seconds_median"] == pytest.approx(0.25)
    assert rep["scan_seconds_p95"] == pytest.approx(
        float(np.percentile([0.1, 0.2, 0.3, 0.4], 95)))
    empty = QueryService(store).latency_report()
    assert empty == {"queries": 0}


# ---------------------------------------------------------------------------
# Cross-dataset serving
# ---------------------------------------------------------------------------

@pytest.fixture()
def two_datasets(tmp_path):
    clips_a, tracks_a = _fleet(seed=1, dataset="dsA")
    clips_b, tracks_b = _fleet(seed=2, dataset="dsB")
    sa = _fake_store(tmp_path / "a", clips_a, tracks_a)
    sb = _fake_store(tmp_path / "b", clips_b, tracks_b)
    service = QueryService({"dsA": sa, "dsB": sb})
    # interleave: scan order must follow the caller's list order
    clips = [c for pair in zip(clips_a, clips_b) for c in pair]
    tracks = [t for pair in zip(tracks_a, tracks_b) for t in pair]
    return service, clips, tracks


def test_cross_dataset_scan_order_determinism(two_datasets):
    service, clips, tracks = two_datasets
    fps = [c.profile.fps for c in clips]
    q = _query((0.0, 0.0, 0.6, 0.6), None, 2, 1, limit=(7, 3))
    res = service.query(q, clips)
    ref = reference_query(tracks, fps, region=(0.0, 0.0, 0.6, 0.6),
                          min_len=2, min_count=1, limit=(7, 3))
    assert res.frames == ref["frames"]
    # and twice more: deterministic across repeats
    assert service.query(q, clips).frames == res.frames
    count = service.query(
        _query(None, None, 2, 1, aggregate="count"), clips)
    ref_c = reference_query(tracks, fps, min_len=2, min_count=1,
                            aggregate="count")
    assert count.aggregates == ref_c["aggregates"]


def test_dataset_scope_routes_and_keeps_indices(two_datasets):
    service, clips, tracks = two_datasets
    fps = [c.profile.fps for c in clips]
    q_all = _query(None, None, 2, 1, aggregate="count")
    total = service.query(q_all, clips).aggregates["count"]
    per = {}
    for ds in ("dsA", "dsB"):
        per[ds] = service.query(q_all.scoped(ds), clips) \
            .aggregates["count"]
    assert per["dsA"] + per["dsB"] == total
    # scoped limit query: frame indices refer to the ORIGINAL list
    q = _query(None, None, 1, 1, limit=(5, 0)).scoped("dsA")
    res = service.query(q, clips)
    a_tracks = [t if c.profile.name == "dsA" else []
                for c, t in zip(clips, tracks)]
    ref = reference_query(a_tracks, fps, min_len=1, min_count=1,
                          limit=(5, 0))
    assert res.frames == ref["frames"]
    assert all(clips[ci].profile.name == "dsA" for ci, _ in res.frames)


def test_plan_run_enforces_dataset_scope_directly():
    """compile_query(q.scoped(...)).run(entries) must honor the scope
    even without the service's pre-filtering."""
    clips_a, tracks_a = _fleet(seed=1, dataset="dsA")
    clips_b, tracks_b = _fleet(seed=2, dataset="dsB")
    entries = _entries(clips_a, tracks_a) + _entries(clips_b, tracks_b)
    q = _query(None, None, 2, 1, aggregate="count")
    total = compile_query(q).run(entries).aggregates["count"]
    only_a = compile_query(q.scoped("dsA")).run(entries) \
        .aggregates["count"]
    only_b = compile_query(q.scoped("dsB")).run(entries) \
        .aggregates["count"]
    assert only_a + only_b == total
    assert only_a == compile_query(q).run(
        _entries(clips_a, tracks_a)).aggregates["count"]


def test_warm_batches_one_ingest_per_store(two_datasets, monkeypatch):
    """An interleaved multi-dataset clip list must reach each store as
    ONE ingest batch (cross-clip prefetch + batch-protected eviction),
    not one degenerate single-clip batch per clip."""
    from repro.query import IngestReport
    service, clips, _ = two_datasets
    calls = []
    for name in ("dsA", "dsB"):
        st = service.stores[name]
        monkeypatch.setattr(st, "has", lambda c: False)

        def fake_ingest(cs, log=None, _name=name):
            calls.append((_name, len(cs)))
            return IngestReport(requested=len(cs), cached=len(cs))

        monkeypatch.setattr(st, "ingest", fake_ingest)
    service.warm(clips)                  # clips alternate dsA/dsB
    assert sorted(calls) == [("dsA", 5), ("dsB", 5)]


def test_unknown_dataset_raises(two_datasets):
    service, clips, _ = two_datasets
    stray = _Clip(_Profile("dsC"), 0, 8)
    with pytest.raises(KeyError):
        service.query(_query(None, None, 1, 1, aggregate="count"),
                      [stray])
    with pytest.raises(AttributeError):
        service.store                    # ambiguous with two stores


# ---------------------------------------------------------------------------
# Real extracted store: index behavior end-to-end
# ---------------------------------------------------------------------------

def test_service_skips_clips_via_index_real_store(qsys):
    bank, params, clips, store, _ = qsys
    service = QueryService(store)
    # caldot1 tracks live in two highway bands around x∈[0.35, 0.65];
    # a far-corner region is provably disjoint from every track bbox
    q = Query.count_frames(region=(0.0, 0.0, 0.02, 0.02))
    res = service.query(q, clips)
    full = service.query(q, clips, use_index=False)
    assert res.aggregates == full.aggregates
    assert res.skipped_clips >= 1
    assert res.scanned_clips < full.scanned_clips
    # an impossible count threshold also skips via max_count summaries
    res2 = service.query(Query.count_frames(min_count=10 ** 6), clips)
    assert res2.skipped_clips == len(clips)
    assert res2.aggregates["count"] == 0


def test_service_histogram_counts_real_store(qsys):
    bank, params, clips, store, _ = qsys
    service = QueryService(store)
    q = Query.count_frames(min_count=1)              # no region: indexed
    res = service.query(q, clips)
    full = service.query(q, clips, use_index=False)
    assert res.aggregates == full.aggregates
    assert res.indexed_clips == res.scanned_clips
    assert full.indexed_clips == 0


def test_class_filter_falls_back_to_scan(qsys):
    bank, params, clips, store, _ = qsys
    service = QueryService(store)
    q = Query.count_tracks(classes=(0,), min_track_len=2)
    res = service.query(q, clips)
    full = service.query(q, clips, use_index=False)
    assert res.aggregates == full.aggregates
    assert res.indexed_clips == 0


def test_service_limit_matches_reference_with_index(qsys):
    bank, params, clips, store, _ = qsys
    service = QueryService(store)
    all_tracks = [store.tracks(c) for c in clips]
    for want, min_count, region, spacing in [
            (8, 1, (0.0, 0.5, 1.0, 1.0), 4),
            (3, 2, (0.0, 0.0, 1.0, 1.0), 0),
            (5, 1, (0.0, 0.0, 0.02, 0.02), 2)]:     # skip-everything
        q = Query.limit_frames(region=region, min_count=min_count,
                               want=want, min_spacing=spacing)
        indexed = service.query(q, clips).frames
        scanned = service.query(q, clips, use_index=False).frames
        assert indexed == scanned == reference_limit_scan(
            all_tracks, want, min_count, region, spacing)


# ---------------------------------------------------------------------------
# Spatial-grid occupancy (coarse 4x4 bitmaps in ClipSummary)
# ---------------------------------------------------------------------------

def _corner_tracks():
    """Two tracks pinned to opposite corners: their union bbox spans
    almost the whole frame, but only two grid cells are occupied."""
    t0 = np.stack([np.arange(4, dtype=np.float32),
                   np.full(4, 0.08, np.float32),
                   np.full(4, 0.08, np.float32),
                   np.full(4, 0.05, np.float32),
                   np.full(4, 0.05, np.float32),
                   np.zeros(4, np.float32)], axis=1)
    t1 = t0.copy()
    t1[:, 1] = t1[:, 2] = 0.92
    t1[:, 5] = 1
    return [t0, t1]


def test_grid_skips_region_inside_bbox_gap():
    """The query region overlaps the union bbox (it sits in the empty
    middle) but intersects no occupied cell — only the grid can prove
    the skip, and the answer matches the full scan bit-identically."""
    clips = [_Clip(_Profile("fake"), 0, 8)]
    entries = [(clips[0], PackedTracks.pack(_corner_tracks(), clips[0]))]
    summary = entries[0][1].summary
    assert summary.grid is not None and len(summary.grid) == \
        len(MIN_LEN_BUCKETS)
    q = _query((0.45, 0.45, 0.55, 0.55), None, 2, 1, aggregate="count")
    plan = compile_query(q)
    assert plan.can_skip(summary)
    res = plan.run(entries)
    full = plan.run(entries, use_index=False)
    assert res.aggregates == full.aggregates
    assert res.skipped_clips == 1 and full.skipped_clips == 0
    # a region covering a corner does NOT skip
    q2 = _query((0.0, 0.0, 0.2, 0.2), None, 2, 1, aggregate="count")
    plan2 = compile_query(q2)
    assert not plan2.can_skip(summary)
    assert plan2.run(entries).aggregates == \
        plan2.run(entries, use_index=False).aggregates


def test_grid_differential_over_fleet(tmp_path):
    """Grid-augmented skipping never changes an answer across the
    query-shape grid (the fleet has clustered, empty and spread
    clips)."""
    clips, all_tracks = _fleet(seed=3)
    entries = _entries(clips, all_tracks)
    for region in ((0.45, 0.45, 0.5, 0.5), (0.02, 0.9, 0.06, 0.99),
                   (0.3, 0.3, 0.8, 0.8)):
        for min_len in (1, 3):
            q = _query(region, None, min_len, 1, aggregate="count")
            plan = compile_query(q)
            a = plan.run(entries)
            b = plan.run(entries, use_index=False)
            assert a.aggregates == b.aggregates, (region, min_len)


def test_grid_survives_json_and_legacy_summaries(tmp_path):
    from repro.query import ClipSummary
    clips = [_Clip(_Profile("fake"), 0, 8)]
    packed = PackedTracks.pack(_corner_tracks(), clips[0])
    summary = packed.summary
    rt = ClipSummary.from_json(
        json.loads(json.dumps(summary.to_json())))
    assert rt == summary
    # a summary persisted before grids existed deserializes with
    # grid=None and the planner falls back to the bbox test
    legacy = dict(summary.to_json())
    del legacy["grid"]
    old = ClipSummary.from_json(legacy)
    assert old.grid is None
    q = _query((0.45, 0.45, 0.55, 0.55), None, 2, 1, aggregate="count")
    plan = compile_query(q)
    assert not plan.can_skip(old)       # bbox alone cannot prove it
    assert plan.can_skip(summary)       # the grid can


def test_grid_real_store_persists(qsys):
    """Executor-extracted store: grids persist through index.json and
    the NPZ; a lane-gap region between caldot1's two highway bands
    skips via the grid with answers identical to the scan."""
    bank, params, clips, store, _ = qsys
    for c in clips:
        s = store.summary(c)
        assert s is not None and s.grid is not None
    service = QueryService(store)
    q = Query.count_frames(region=(0.02, 0.02, 0.06, 0.06))
    res = service.query(q, clips)
    full = service.query(q, clips, use_index=False)
    assert res.aggregates == full.aggregates
