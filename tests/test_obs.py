"""Observability layer (``repro.obs``): the no-perturbation contract —
tracing off records nothing and tracing on never changes any pipeline
output — plus the span exporters, the metrics registry, the shared
stage-timing assembly, the drift monitors and the pure latency-report
aggregation."""
import json
import threading

import numpy as np
import pytest

from repro.configs.multiscope import MULTISCOPE_PIPELINE
from repro.core import pipeline as pl
from repro.core.executor import (BatchBroker, ExecutorOptions,
                                 TrackBroker, run_clip_streamed)
from repro.core.proxy import ProxyModel
from repro.core.tracker import init_tracker
from repro.core.train_models import train_detector
from repro.data.video_synth import make_split
from repro.obs import metrics as om
from repro.obs.metrics import (REGISTRY, DriftMonitor, Registry,
                               RunProfile, assert_stage_sane,
                               disable_drift, empty_stage_block,
                               enable_drift, merge_stage_blocks,
                               stage_block)
from repro.obs.trace import TRACER


@pytest.fixture(autouse=True)
def _obs_clean():
    """Every test leaves the module-level tracer off and empty and the
    drift flag cleared — no cross-test leakage."""
    yield
    TRACER.disable()
    TRACER.clear()
    disable_drift()


@pytest.fixture(scope="module")
def exec_bank():
    cfg = MULTISCOPE_PIPELINE.reduced()
    clips = make_split("caldot1", "train", 2, n_frames=24)
    det, _ = train_detector("ssd-lite", clips,
                            [cfg.detector.resolutions[-1]], steps=60)
    bank = pl.ModelBank(cfg, {"ssd-lite": det, "ssd-deep": det})
    res = cfg.proxy.resolutions[-1]
    proxy = ProxyModel(cfg.proxy.cell, cfg.proxy.base_channels, res)
    bank.proxies = {res: proxy}
    bank.sizes_cells = [pl.det_grid(cfg.detector.resolutions[-1]),
                        (3, 2), (5, 3)]
    bank.ref_grid = pl.det_grid(cfg.detector.resolutions[-1])
    bank.tracker_params = init_tracker(cfg.tracker)
    W, H = cfg.detector.resolutions[-1]
    frame, _ = pl.render_frame(clips[0], 0, W, H)
    s, _ = proxy.scores(pl._downsample(frame, res))
    return bank, clips, res, float(np.quantile(s, 0.85))


def _params(bank, res, th, **kw):
    base = dict(det_arch="ssd-lite",
                det_res=bank.cfg.detector.resolutions[-1],
                det_conf=0.4, gap=1, proxy_res=res, proxy_threshold=th,
                tracker="sort", refine=False)
    base.update(kw)
    return pl.PipelineParams(**base)


def _flavors(bank, params, clip):
    """The four executor flavors the bit-identity acceptance names.
    Each returns (tracks, dispatches) for one run of ``clip``."""

    def sequential():
        r = pl.run_clip_frames(bank, params, clip)
        return r.tracks, None

    def streaming():
        r = run_clip_streamed(bank, params, clip,
                              ExecutorOptions(prefetch=False))
        return r.tracks, r.dispatches

    def device_tracker():
        r = run_clip_streamed(
            bank, params, clip,
            ExecutorOptions(prefetch=False, device_tracker=True))
        return r.tracks, r.dispatches

    def track_broker():
        tb = TrackBroker()
        try:
            r = run_clip_streamed(
                bank, params, clip,
                ExecutorOptions(prefetch=False, device_assign=True,
                                track_broker=tb))
        finally:
            tb.close()
        return r.tracks, r.dispatches

    return {"sequential": sequential, "streaming": streaming,
            "device_tracker": device_tracker,
            "track_broker": track_broker}


# ---------------------------------------------------------------------------
# the no-perturbation contract
# ---------------------------------------------------------------------------

def test_disabled_tracer_records_nothing(exec_bank):
    """Tracing off (the default): a full streamed run leaves the ring
    buffer empty — the instrumentation sites never reach the tracer."""
    bank, clips, res, th = exec_bank
    TRACER.disable()
    TRACER.clear()
    run_clip_streamed(bank, _params(bank, res, th), clips[0],
                      ExecutorOptions(prefetch=False))
    assert TRACER.snapshot() == []
    assert TRACER.current() is None


def test_tracing_on_is_bit_identical_across_flavors(exec_bank):
    """The acceptance gate: for each executor flavor, tracks AND
    dispatch counts with tracing enabled equal the tracing-off run bit
    for bit — the tracer observes, never perturbs."""
    bank, clips, res, th = exec_bank
    params = _params(bank, res, th, chunk_size=8)
    for clip in clips:
        for name, flavor in _flavors(bank, params, clip).items():
            TRACER.disable()
            ref_tracks, ref_disp = flavor()
            TRACER.enable()
            TRACER.clear()
            got_tracks, got_disp = flavor()
            n_spans = len(TRACER.snapshot())
            TRACER.disable()
            assert got_disp == ref_disp, (name, got_disp, ref_disp)
            assert len(got_tracks) == len(ref_tracks), name
            for a, b in zip(ref_tracks, got_tracks):
                np.testing.assert_array_equal(a, b, err_msg=name)
            if name != "sequential":      # per-frame path is untraced
                assert n_spans > 0, f"{name}: tracing on emitted no spans"


def test_tracing_collects_run_and_stage_spans(exec_bank):
    """An enabled streamed run emits one ``run`` root and per-chunk
    ``stage.*`` children parented to it, all tagged with the stream."""
    bank, clips, res, th = exec_bank
    TRACER.enable()
    TRACER.clear()
    run_clip_streamed(bank, _params(bank, res, th, chunk_size=8),
                      clips[0], ExecutorOptions(prefetch=False))
    spans = TRACER.snapshot()
    TRACER.disable()
    roots = [s for s in spans if s.name == "run"]
    assert len(roots) == 1 and roots[0].dur >= 0
    stages = [s for s in spans if s.name.startswith("stage.")]
    assert {s.name for s in stages} >= {"stage.decode", "stage.proxy"}
    for s in stages:
        assert s.parent == roots[0].sid
        assert s.stream == roots[0].stream
        assert s.dur >= 0 and s.proc >= 0


# ---------------------------------------------------------------------------
# exporters
# ---------------------------------------------------------------------------

def test_chrome_export_16_stream_broker_run(exec_bank, tmp_path):
    """16 concurrent per-frame streams through one BatchBroker export a
    valid Chrome trace: loads with ``json.load``, one pid lane per
    stream plus the shared broker lane, X events with monotone
    non-negative microsecond timestamps."""
    bank, clips, res, th = exec_bank
    params = _params(bank, res, th, chunk_size=1)
    TRACER.enable()
    TRACER.clear()
    broker = BatchBroker()
    results = [None] * 16
    errors = []

    def one(i):
        try:
            results[i] = run_clip_streamed(
                bank, params, clips[i % len(clips)],
                ExecutorOptions(prefetch=False, batch_broker=broker))
        except BaseException as exc:
            errors.append(exc)

    threads = [threading.Thread(target=one, args=(i,))
               for i in range(16)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    broker.close()
    assert not errors, errors
    path = tmp_path / "trace.json"
    n = TRACER.export_chrome(str(path))
    TRACER.disable()
    with open(path) as f:
        events = json.load(f)          # round-trips as plain JSON
    xs = [e for e in events if e["ph"] == "X"]
    metas = [e for e in events if e["ph"] == "M"]
    assert len(xs) == n > 0
    # one process lane per stream + the shared broker lane
    lanes = {m["args"]["name"] for m in metas}
    assert "(shared)" in lanes and len(lanes) == len(clips) + 1
    last = -1.0
    for e in xs:
        assert e["ts"] >= last >= -1.0     # sorted ascending
        assert e["dur"] >= 0.0
        last = e["ts"]
    assert any(e["name"] == "broker.detect.flush" for e in xs)
    assert any(e["name"] == "run" for e in xs)


def test_jsonl_export_roundtrip(tmp_path):
    """JSON-lines export: one parseable object per span, sorted by
    start time, parent links preserved."""
    TRACER.enable()
    TRACER.clear()
    with TRACER.span("outer", "test", stream="cam0") as so:
        with TRACER.span("inner", "test") as si:
            assert si.parent == so.sid
    TRACER.emit("follow", "test", ts=si.ts + si.dur + 1, dur=5)
    path = tmp_path / "spans.jsonl"
    n = TRACER.export_jsonl(str(path))
    TRACER.disable()
    lines = [json.loads(ln) for ln in open(path)]
    assert len(lines) == n == 3
    assert [ln["name"] for ln in lines] == ["outer", "inner", "follow"]
    by_name = {ln["name"]: ln for ln in lines}
    assert by_name["inner"]["parent"] == by_name["outer"]["sid"]
    assert by_name["outer"]["stream"] == "cam0"
    ts = [ln["ts_ns"] for ln in lines]
    assert ts == sorted(ts)
    assert all(ln["dur_ns"] >= 0 for ln in lines)


def test_ring_buffer_bounds_memory():
    tr = TRACER
    tr.enable(capacity=16)
    tr.clear()
    for i in range(100):
        tr.emit("e", ts=i, dur=1)
    spans = tr.snapshot()
    tr.disable()
    tr.enable(capacity=65536)        # restore the default capacity
    tr.disable()
    assert len(spans) == 16
    assert spans[0].ts == 84 and spans[-1].ts == 99


# ---------------------------------------------------------------------------
# metrics registry
# ---------------------------------------------------------------------------

def test_registry_kinds_and_snapshot():
    reg = Registry()
    reg.counter("a.hits").inc(3)
    reg.gauge("a.depth").set(2.5)
    h = reg.histogram("b.lat")
    for v in (1.0, 2.0, 3.0, 4.0):
        h.observe(v)
    snap = reg.snapshot()
    assert snap["a.hits"] == 3 and snap["a.depth"] == 2.5
    assert snap["b.lat"]["count"] == 4
    assert snap["b.lat"]["mean"] == pytest.approx(2.5)
    assert snap["b.lat"]["min"] == 1.0 and snap["b.lat"]["max"] == 4.0
    assert snap["b.lat"]["p50"] == pytest.approx(2.5)
    # prefix filter
    assert set(reg.snapshot("a.")) == {"a.hits", "a.depth"}
    # a name keeps its kind
    with pytest.raises(TypeError):
        reg.gauge("a.hits")
    # the whole snapshot is JSON-serializable (benches embed it)
    json.dumps(snap)


def test_registry_reset_keeps_cached_references():
    """Instrumentation sites cache metric objects at construction;
    ``reset`` must zero IN PLACE so those references stay live."""
    reg = Registry()
    c = reg.counter("x.n")
    c.inc(7)
    reg.reset()
    assert c.value == 0
    c.inc()
    assert reg.snapshot()["x.n"] == 1
    assert reg.counter("x.n") is c


def test_registry_is_thread_safe():
    reg = Registry()
    c = reg.counter("t.n")
    h = reg.histogram("t.h")

    def work():
        for _ in range(1000):
            c.inc()
            h.observe(1.0)

    threads = [threading.Thread(target=work) for _ in range(8)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert c.value == 8000 and h.count == 8000


def test_global_registry_populated_by_pipeline(exec_bank):
    """A streamed run folds its stage timings and dispatch counts into
    the module-level REGISTRY under the documented names."""
    bank, clips, res, th = exec_bank
    REGISTRY.reset()
    r = run_clip_streamed(bank, _params(bank, res, th), clips[0],
                          ExecutorOptions(prefetch=False))
    snap = REGISTRY.snapshot()
    assert snap["executor.dispatch.proxy"] == r.dispatches["proxy"]
    assert snap["executor.dispatch.detect"] == r.dispatches["detect"]
    for st in r.stage_seconds:
        assert snap[f"executor.stage.{st}.wall_seconds"]["count"] >= 1
    assert snap["detector.dispatches"] >= r.dispatches["detect"]


# ---------------------------------------------------------------------------
# stage-timing assembly (the shared helper the benches use)
# ---------------------------------------------------------------------------

def test_stage_block_helpers():
    b = stage_block({"decode": 1.0, "proxy": 2.0}, {"decode": 0.5})
    assert b == {"decode": {"wall": 1.0, "process": 0.5},
                 "proxy": {"wall": 2.0, "process": 0.0}}
    assert empty_stage_block(["a"]) == {"a": {"wall": 0.0,
                                              "process": 0.0}}
    merged = merge_stage_blocks([b, None, b])
    assert merged["decode"] == {"wall": 2.0, "process": 1.0}
    assert merged["proxy"]["wall"] == 4.0
    assert_stage_sane(merged)
    assert_stage_sane(None)
    with pytest.raises(AssertionError):
        assert_stage_sane({"x": {"wall": 0.1, "process": 0.5}})


def test_run_profile_thread_safe_and_publishes():
    prof = RunProfile(["decode", "detect"])

    def work():
        for _ in range(500):
            prof.note_stage("decode", 0.001, 0.0005)
            prof.dispatch("detect")

    threads = [threading.Thread(target=work) for _ in range(4)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    ss = prof.stage_seconds()
    assert ss["decode"]["wall"] == pytest.approx(2.0)
    assert ss["decode"]["process"] == pytest.approx(1.0)
    assert prof.dispatches("detect") == 2000
    assert_stage_sane(ss)
    reg = Registry()
    prof.publish(reg, prefix="executor")
    snap = reg.snapshot()
    assert snap["executor.dispatch.detect"] == 2000
    assert snap["executor.stage.decode.wall_seconds"]["count"] == 1


# ---------------------------------------------------------------------------
# drift monitors
# ---------------------------------------------------------------------------

def test_drift_monitor_flags_content_shift():
    mon = DriftMonitor(window=4, trailing=8)
    for w in range(12):                      # steady regime
        mon.observe(w, proxy_fracs=[0.2, 0.22], track_count=3)
    assert not mon.drifted()
    s = mon.summary()
    assert s["watermarks"] == 12 and s["last_watermark"] == 11
    assert s["proxy_score"]["delta"] == pytest.approx(0.0)
    assert sum(s["proxy_score"]["hist"]) == 12
    for w in range(12, 16):                  # content shift
        mon.observe(w, proxy_fracs=[0.8], track_count=9)
    assert mon.drifted()
    s = mon.summary()
    assert s["proxy_score"]["delta"] > 0.3
    assert s["track_count"]["delta"] > 2.0


def test_drift_collection_is_opt_in(exec_bank):
    """proxy_fracs ride on RunResult only while drift is enabled, and
    enabling it never changes the tracks."""
    bank, clips, res, th = exec_bank
    params = _params(bank, res, th)
    opts = ExecutorOptions(prefetch=False)
    r_off = run_clip_streamed(bank, params, clips[0], opts)
    assert r_off.proxy_fracs is None
    enable_drift()
    try:
        r_on = run_clip_streamed(bank, params, clips[0], opts)
    finally:
        disable_drift()
    assert r_on.proxy_fracs is not None
    assert len(r_on.proxy_fracs) == r_on.frames_processed
    assert all(0.0 <= f <= 1.0 for f in r_on.proxy_fracs)
    for a, b in zip(r_off.tracks, r_on.tracks):
        np.testing.assert_array_equal(a, b)


# ---------------------------------------------------------------------------
# latency-report aggregation (pure function)
# ---------------------------------------------------------------------------

def test_summarize_latency_per_dataset_and_counters():
    from repro.query.service import QueryStats, summarize_latency

    assert summarize_latency([]) == {"queries": 0}
    hist = [
        QueryStats(scan_seconds=0.1, ingest_seconds=0.0,
                   skipped_clips=1, indexed_clips=1, scanned_clips=1,
                   n_clips=3, datasets="caldot1"),
        QueryStats(scan_seconds=0.3, ingest_seconds=0.2,
                   ingested_clips=2, scanned_clips=2, n_clips=2,
                   datasets="caldot1"),
        QueryStats(scan_seconds=0.2, indexed_clips=4, n_clips=4,
                   datasets="caldot1+shibuya"),
        QueryStats(scan_seconds=0.4, n_clips=0),      # no datasets
    ]
    rep = summarize_latency(hist)
    # flat keys bit-compatible with the pre-breakdown report
    assert rep["queries"] == 4
    assert rep["warm_queries"] == 3
    assert rep["scan_seconds_total"] == pytest.approx(1.0)
    assert rep["scan_seconds_median"] == pytest.approx(0.25)
    assert rep["ingest_seconds_total"] == pytest.approx(0.2)
    # clip-disposition totals (what plan.run always computed)
    assert rep["clips_skipped_total"] == 1
    assert rep["clips_indexed_total"] == 5
    assert rep["clips_scanned_total"] == 3
    assert rep["clips_total"] == 9
    # per-dataset breakdown groups on the "+"-joined touched sets
    ds = rep["datasets"]
    assert set(ds) == {"caldot1", "caldot1+shibuya", "(none)"}
    assert ds["caldot1"]["queries"] == 2
    assert ds["caldot1"]["warm_queries"] == 1
    assert ds["caldot1+shibuya"]["scan_seconds_median"] \
        == pytest.approx(0.2)
    assert ds["(none)"]["queries"] == 1
    json.dumps(rep)                 # benches embed it verbatim


def test_query_service_latency_report_live(exec_bank, tmp_path):
    """End to end: real queries against a warm store produce the
    per-dataset breakdown and clip counters."""
    from repro.query import Query, QueryService, TrackStore

    bank, clips, res, th = exec_bank
    store = TrackStore(str(tmp_path / "store"), bank,
                       _params(bank, res, th))
    service = QueryService(store)
    service.warm(clips)
    for _ in range(3):
        service.query(Query.count_frames(min_count=1), clips)
    rep = service.latency_report()
    assert rep["queries"] >= 3
    assert rep["clips_total"] >= 3 * len(clips)
    assert set(rep["datasets"]) == {"caldot1"}
    assert rep["datasets"]["caldot1"]["queries"] == rep["queries"]
