"""Equivalence tests for the streaming clip executor: bit-identical
tracks and decode-ledger counters vs the per-frame reference path for
every chunk size / scheduler / prefetch setting, plus the tuner's
chunk-size (scheduler module) proposal path."""
import dataclasses

import numpy as np
import pytest

from repro.configs.multiscope import MULTISCOPE_PIPELINE
from repro.core import pipeline as pl
from repro.core import tuner as tuner_mod
from repro.core.executor import (DEFAULT_CHUNK, ClipExecutor,
                                 DecodePool, ExecutorOptions,
                                 TrackBroker, effective_chunk,
                                 run_clip_streamed, run_clips)
from repro.core.proxy import ProxyModel
from repro.core.tracker import init_tracker
from repro.core.train_models import train_detector
from repro.data.video_synth import make_split


@pytest.fixture(scope="module")
def exec_bank():
    cfg = MULTISCOPE_PIPELINE.reduced()
    clips = make_split("caldot1", "train", 2, n_frames=24)
    det, _ = train_detector("ssd-lite", clips,
                            [cfg.detector.resolutions[-1]], steps=60)
    bank = pl.ModelBank(cfg, {"ssd-lite": det, "ssd-deep": det})
    res = cfg.proxy.resolutions[-1]
    proxy = ProxyModel(cfg.proxy.cell, cfg.proxy.base_channels, res)
    bank.proxies = {res: proxy}
    bank.sizes_cells = [pl.det_grid(cfg.detector.resolutions[-1]),
                        (3, 2), (5, 3)]
    bank.ref_grid = pl.det_grid(cfg.detector.resolutions[-1])
    bank.tracker_params = init_tracker(cfg.tracker)
    # a threshold just above the untrained proxy's score median makes
    # the positive-cell grid SPARSE, so planning emits real sub-frame
    # windows (the interesting path for the gather/upload machinery)
    W, H = cfg.detector.resolutions[-1]
    frame, _ = pl.render_frame(clips[0], 0, W, H)
    s, _ = proxy.scores(pl._downsample(frame, res))
    return bank, clips, res, float(np.quantile(s, 0.85))


def _assert_same(a, b):
    """Tracks bit-identical; decode-ledger counters equal."""
    assert a.frames_processed == b.frames_processed
    assert a.detector_windows == b.detector_windows
    assert a.full_frames == b.full_frames
    assert a.skipped_frames == b.skipped_frames
    assert len(a.tracks) == len(b.tracks)
    for x, y in zip(a.tracks, b.tracks):
        np.testing.assert_array_equal(x, y)


def _params(bank, res, th, **kw):
    base = dict(det_arch="ssd-lite",
                det_res=bank.cfg.detector.resolutions[-1],
                det_conf=0.4, gap=1, proxy_res=res, proxy_threshold=th,
                tracker="sort", refine=False)
    base.update(kw)
    return pl.PipelineParams(**base)


# 24-frame clips at gap=1: B=1 degenerates to per-frame chunks, B=7
# leaves a trailing partial chunk of 3, B=16 leaves one of 8, B=33
# exceeds the clip (single partial chunk)
@pytest.mark.parametrize("chunk", [1, 7, 16, 33])
@pytest.mark.parametrize("prefetch", [False, True])
def test_executor_equivalence_chunk_sizes(exec_bank, chunk, prefetch):
    bank, clips, res, th = exec_bank
    params = _params(bank, res, th, chunk_size=chunk)
    opts = ExecutorOptions(prefetch=prefetch)
    for clip in clips:
        _assert_same(pl.run_clip_frames(bank, params, clip),
                     run_clip_streamed(bank, params, clip, opts))


def test_executor_prefetch_recurrent(exec_bank):
    """The recurrent tracker under the streaming scheduler: chunked
    crop embeddings + prefetch must reproduce the per-frame path
    bit-exactly."""
    bank, clips, res, th = exec_bank
    params = _params(bank, res, th, tracker="recurrent")
    for clip in clips:
        _assert_same(pl.run_clip_frames(bank, params, clip),
                     run_clip_streamed(bank, params, clip))


def test_executor_empty_detections_clip(exec_bank):
    """Impossible proxy threshold: every frame skipped, zero detections
    anywhere — the executor must agree with the reference on the empty
    case too (no stray uploads, no tracker steps with stale state)."""
    bank, clips, res, _ = exec_bank
    params = _params(bank, res, 0.9999999, gap=2, chunk_size=7)
    r = run_clip_streamed(bank, params, clips[0])
    assert r.skipped_frames == r.frames_processed
    assert all(len(t) == 0 for t in r.tracks)
    _assert_same(pl.run_clip_frames(bank, params, clips[0]), r)


def test_executor_run_clips_matches_per_clip(exec_bank):
    """The multi-clip sweep (cross-clip decode prefetch, per-clip device
    offsets) returns exactly the per-clip results in order."""
    bank, clips, res, th = exec_bank
    params = _params(bank, res, th, tracker="recurrent")
    results, total = run_clips(bank, params, clips)
    assert len(results) == len(clips)
    for clip, r in zip(clips, results):
        _assert_same(pl.run_clip_frames(bank, params, clip), r)
    assert total == pytest.approx(sum(r.seconds for r in results))


def test_executor_mesh_sharded_upload(exec_bank):
    """Chunk uploads through LogicalRules mesh sharding (batch axis on
    the data axis) stay bit-identical."""
    from repro.launch.mesh import make_host_mesh
    bank, clips, res, th = exec_bank
    params = _params(bank, res, th)
    opts = ExecutorOptions(mesh=make_host_mesh(1, 1))
    _assert_same(pl.run_clip_frames(bank, params, clips[0]),
                 run_clip_streamed(bank, params, clips[0], opts))


def test_run_clip_streaming_dispatch(exec_bank):
    """pipeline.run_clip routes to the streaming executor by default;
    all three engines agree."""
    bank, clips, res, th = exec_bank
    params = _params(bank, res, th, gap=2)
    a = pl.run_clip(bank, params, clips[0])
    _assert_same(a, pl.run_clip(bank, params, clips[0],
                                engine="chunked"))
    _assert_same(a, pl.run_clip(bank, params, clips[0], engine="frame"))
    with pytest.raises(ValueError):
        pl.run_clip(bank, params, clips[0], engine="nope")


def test_executor_stage_failure_propagates(exec_bank):
    """A stage exception mid-stream must propagate promptly: the decode
    worker is blocked in q.put on the full bounded queue when the
    failure hits, and drain has to unblock it before re-raising (a bare
    join would deadlock forever)."""
    bank, clips, res, th = exec_bank
    params = _params(bank, res, th, chunk_size=1)   # chunks >> depth

    def boom(ctx, task):
        raise RuntimeError("detect failed")

    ex = ClipExecutor(bank, params, ExecutorOptions(prefetch=True),
                      stages={"detect": boom})
    with pytest.raises(RuntimeError, match="detect failed"):
        ex.run(clips[0])


def test_executor_cancel_releases_started_run(exec_bank):
    """run_clips starts clip i+1's decode ahead; an abandoned run must
    be cancellable without draining it (its worker would otherwise
    block forever holding decoded chunks)."""
    bank, clips, res, th = exec_bank
    params = _params(bank, res, th, chunk_size=1)
    ex = ClipExecutor(bank, params, ExecutorOptions(prefetch=True,
                                                    decode_workers=2))
    run = ex.start(clips[0])
    ex.cancel(run)                       # must return, not hang
    _, worker_threads, _, _ = run.handle
    assert not any(t.is_alive() for t in worker_threads)


def test_effective_chunk_resolution():
    p = pl.PipelineParams("ssd-lite", (128, 80), 0.4)
    assert effective_chunk(p) == DEFAULT_CHUNK
    assert effective_chunk(dataclasses.replace(p, chunk_size=32)) == 32
    assert effective_chunk(dataclasses.replace(p, chunk_size=32),
                           override=8) == 8


# ---------------------------------------------------------------------------
# Decode worker pool (ExecutorOptions.decode_workers)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("workers", [2, 3])
def test_executor_decode_worker_pool(exec_bank, workers):
    """N decode workers + the reorder gate must reproduce the
    single-thread schedule bit-exactly (chunks reach TRACK in frame
    order regardless of which worker decoded them first)."""
    bank, clips, res, th = exec_bank
    params = _params(bank, res, th, chunk_size=4)   # several chunks
    opts = ExecutorOptions(prefetch=True, prefetch_depth=2,
                           decode_workers=workers)
    for clip in clips:
        _assert_same(pl.run_clip_frames(bank, params, clip),
                     run_clip_streamed(bank, params, clip, opts))


def test_run_clips_decode_worker_pool(exec_bank):
    """The pool option threads through the multi-clip sweep."""
    bank, clips, res, th = exec_bank
    params = _params(bank, res, th, chunk_size=4)
    results, _ = run_clips(bank, params, clips,
                           ExecutorOptions(decode_workers=2))
    for clip, r in zip(clips, results):
        _assert_same(pl.run_clip_frames(bank, params, clip), r)


def test_executor_pool_failure_propagates(exec_bank):
    """A mid-stream stage failure with a worker pool: every worker —
    including ones parked at the reorder gate or blocked on the full
    queue — must be released before the error propagates."""
    bank, clips, res, th = exec_bank
    params = _params(bank, res, th, chunk_size=1)   # chunks >> depth

    def boom(ctx, task):
        raise RuntimeError("detect failed")

    ex = ClipExecutor(bank, params,
                      ExecutorOptions(prefetch=True, decode_workers=3),
                      stages={"detect": boom})
    with pytest.raises(RuntimeError, match="detect failed"):
        ex.run(clips[0])
    # all pool threads must have exited (no leaked decoders)
    import threading as _t
    assert not [t for t in _t.enumerate()
                if t.name.startswith("multiscope-decode")
                and t.is_alive()]


# ---------------------------------------------------------------------------
# Shared decode pool (one pool across the in-flight clips of run_clips)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("pool_size", [1, 3])
def test_run_clips_shared_pool_bit_identical(exec_bank, pool_size):
    """One DecodePool shared by the two in-flight clips: per-clip
    reorder gates must keep TRACK frame-ordered, so tracks stay
    bit-identical to the per-frame reference for any pool size."""
    bank, clips, res, th = exec_bank
    params = _params(bank, res, th, chunk_size=4)
    pool = DecodePool(pool_size)
    try:
        results, _ = run_clips(bank, params, clips,
                               ExecutorOptions(decode_pool=pool))
        for clip, r in zip(clips, results):
            _assert_same(pl.run_clip_frames(bank, params, clip), r)
        # an external pool is reusable across sweeps
        results2, _ = run_clips(bank, params, clips,
                                ExecutorOptions(decode_pool=pool))
        for a, b in zip(results, results2):
            _assert_same(a, b)
    finally:
        pool.close()


def test_run_clips_owns_pool_by_default(exec_bank):
    """run_clips with default options creates (and closes) its own
    shared pool; no pool threads may leak."""
    import threading as _t
    bank, clips, res, th = exec_bank
    params = _params(bank, res, th, chunk_size=4)
    results, _ = run_clips(bank, params, clips)
    for clip, r in zip(clips, results):
        _assert_same(pl.run_clip_frames(bank, params, clip), r)
    assert not [t for t in _t.enumerate()
                if t.name.startswith("multiscope-pool-decode")
                and t.is_alive()]


def test_shared_pool_failure_releases_workers(exec_bank):
    """A stage failure mid-stream under the shared pool: the error
    propagates, the pool's workers survive (they are shared), and the
    pool still closes cleanly."""
    import threading as _t
    bank, clips, res, th = exec_bank
    params = _params(bank, res, th, chunk_size=1)   # chunks >> depth

    def boom(ctx, task):
        raise RuntimeError("detect failed")

    pool = DecodePool(2)
    try:
        ex = ClipExecutor(bank, params,
                          ExecutorOptions(decode_pool=pool),
                          stages={"detect": boom})
        with pytest.raises(RuntimeError, match="detect failed"):
            ex.run(clips[0])
        # workers are still alive and serviceable after the failure
        ex_ok = ClipExecutor(bank, params,
                             ExecutorOptions(decode_pool=pool))
        _assert_same(pl.run_clip_frames(bank, params, clips[0]),
                     ex_ok.run(clips[0]))
    finally:
        pool.close()
    assert not [t for t in _t.enumerate()
                if t.name.startswith("multiscope-pool-decode")
                and t.is_alive()]


def test_executor_segment_resume_hooks(exec_bank):
    """start(frame_ids=..., tracker=...): running a clip as two
    resumed slices reproduces the one-shot run bit-exactly (the hook
    repro.stream builds on)."""
    bank, clips, res, th = exec_bank
    params = _params(bank, res, th, tracker="recurrent", gap=2)
    clip = clips[0]
    ref = pl.run_clip_frames(bank, params, clip)
    ex = ClipExecutor(bank, params)
    ids = list(range(0, clip.n_frames, params.gap))
    cut = len(ids) // 2
    from repro.core.tracker import RecurrentTracker
    tracker = RecurrentTracker(bank.cfg.tracker, bank.tracker_params)
    r1 = ex.finish(ex.start(clip, frame_ids=ids[:cut], tracker=tracker))
    r2 = ex.finish(ex.start(clip, frame_ids=ids[cut:], tracker=tracker))
    assert r1.frames_processed + r2.frames_processed \
        == ref.frames_processed
    assert r1.detector_windows + r2.detector_windows \
        == ref.detector_windows
    assert len(ref.tracks) == len(r2.tracks)
    for a, b in zip(ref.tracks, r2.tracks):
        np.testing.assert_array_equal(a, b)


# ---------------------------------------------------------------------------
# Device-resident TRACK: per-step device assignment, chunk-scan tracker,
# cross-stream track batching
# ---------------------------------------------------------------------------

def test_executor_stage_seconds_and_dispatches(exec_bank):
    """RunResult carries per-stage wall/process seconds and dispatch
    counts for every named stage, and they are internally consistent
    (non-negative, process <= a generous multiple of wall)."""
    bank, clips, res, th = exec_bank
    params = _params(bank, res, th, tracker="recurrent", chunk_size=7)
    r = run_clip_streamed(bank, params, clips[0])
    assert r.stage_seconds is not None
    assert set(r.stage_seconds) == {"decode", "proxy", "detect", "track"}
    for s, d in r.stage_seconds.items():
        assert d["wall"] >= 0.0 and d["process"] >= 0.0, (s, d)
    assert r.dispatches is not None
    assert set(r.dispatches) == {"proxy", "detect", "track"}
    assert r.dispatches["proxy"] > 0
    assert r.dispatches["track"] > 0


def test_executor_device_assign_roundtrip(exec_bank):
    """ExecutorOptions(device_assign=True) routes the recurrent
    tracker's per-step association through the fused track-step kernel
    and reproduces the host path bit-exactly; the flag round-trips to
    the tracker and the device steps are counted."""
    bank, clips, res, th = exec_bank
    params = _params(bank, res, th, tracker="recurrent", chunk_size=7)
    for clip in clips:
        ref = run_clip_streamed(bank, params, clip)
        ex = ClipExecutor(bank, params,
                          ExecutorOptions(device_assign=True))
        run = ex.start(clip)
        assert getattr(run.ctx.tracker, "assign", None) == "device"
        dev = ex.finish(run)
        _assert_same(ref, dev)
        # every chunk embeds once; device steps add per-frame dispatches
        assert dev.dispatches["track"] > ref.dispatches["track"]


@pytest.mark.parametrize("chunk", [1, 16])
def test_executor_device_tracker_equivalence(exec_bank, chunk):
    """device_tracker=True executes whole chunks as one scan dispatch
    and stays bit-identical to the host tracker for any chunking."""
    bank, clips, res, th = exec_bank
    params = _params(bank, res, th, tracker="recurrent",
                     chunk_size=chunk)
    for clip in clips:
        ref = run_clip_streamed(bank, params, clip)
        dev = run_clip_streamed(bank, params, clip,
                                ExecutorOptions(device_tracker=True))
        _assert_same(ref, dev)


def test_track_broker_multi_stream_bit_identical(exec_bank):
    """Two concurrent streams sharing a TrackBroker: per-frame device
    track steps coalesce into batched dispatches, results stay
    bit-identical per stream, and the broker's ledger accounts for
    every step."""
    import threading
    bank, clips, res, th = exec_bank
    params = _params(bank, res, th, tracker="recurrent", chunk_size=7)
    broker = TrackBroker(linger_ms=2.0)
    opts = ExecutorOptions(device_assign=True, track_broker=broker)
    ex = ClipExecutor(bank, params, opts)
    out = [None] * len(clips)

    def run(i):
        out[i] = ex.run(clips[i])

    ts = [threading.Thread(target=run, args=(i,))
          for i in range(len(clips))]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    broker.close()
    for i, clip in enumerate(clips):
        _assert_same(run_clip_streamed(bank, params, clip), out[i])
    assert 0 < broker.dispatches <= broker.steps_in
    # one fill entry per dispatch; their sum is every step admitted
    assert len(broker.stream_fill) == broker.dispatches
    assert sum(broker.stream_fill) == broker.steps_in


# ---------------------------------------------------------------------------
# The tuner's scheduler module (chunk-size proposals)
# ---------------------------------------------------------------------------

def test_tuner_chunk_proposal_gating():
    p = pl.PipelineParams("ssd-lite", (128, 80), 0.4, gap=1)
    # dense full-frame θ: nothing to amortize
    assert tuner_mod.propose_chunk(p) is None
    # sparse θ (gap >= 2): double B from the default
    sparse = dataclasses.replace(p, gap=4)
    c = tuner_mod.propose_chunk(sparse)
    assert c is not None and c.chunk_size == 2 * DEFAULT_CHUNK
    # proxy-gated θ proposes too
    gated = dataclasses.replace(p, proxy_res=(32, 24))
    assert tuner_mod.propose_chunk(gated).chunk_size == 2 * DEFAULT_CHUNK
    # doubling continues from θ's current B and stops at the ceiling
    c2 = tuner_mod.propose_chunk(dataclasses.replace(sparse,
                                                     chunk_size=32))
    assert c2.chunk_size == 64
    assert tuner_mod.propose_chunk(
        dataclasses.replace(sparse, chunk_size=64)) is None


def test_tuner_chunk_proposal_accuracy_neutral(exec_bank):
    """The chunk-size-tuning path end to end: a scheduler-module
    candidate evaluated through the tuner must reproduce the current
    θ's accuracy exactly (tracks are bit-identical across B), so it can
    only ever win on the runtime tiebreak."""
    bank, clips, res, th = exec_bank
    cur = _params(bank, res, th, gap=2)
    cand = tuner_mod.propose_chunk(cur)
    assert cand is not None and cand != cur
    acc_cur, _ = tuner_mod._evaluate(bank, cur, clips)
    acc_cand, _ = tuner_mod._evaluate(bank, cand, clips)
    assert acc_cand == pytest.approx(acc_cur, abs=0)
