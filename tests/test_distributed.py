"""Distribution substrate tests: sharding rules, checkpoint/restart,
supervisor crash recovery, elastic re-mesh, gradient compression, and the
HLO stats parser."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh, PartitionSpec as P

from repro.distributed.checkpoint import Checkpointer
from repro.distributed.compression import (ef_roundtrip,
                                           init_error_buffer)
from repro.distributed.elastic import elastic_mesh_shape
from repro.distributed.fault import HeartbeatMonitor, Supervisor
from repro.distributed.sharding import LogicalRules


def _mesh_1():
    return Mesh(np.array(jax.devices()[:1]).reshape(1, 1),
                ("data", "model"))


# ---------------------------------------------------------------------------
# Sharding rules
# ---------------------------------------------------------------------------

class _FakeMesh:
    def __init__(self, shape, names):
        self.axis_names = names
        self.devices = np.empty(shape)


def test_rules_divisibility_fallback():
    rules = LogicalRules(_FakeMesh((16, 16), ("data", "model")))
    # 14 heads don't divide 16 -> replicate that dim
    spec = rules.pspec_for_shape((8, 128, 14, 64),
                                 ("batch", "seq", "heads", None))
    assert spec[2] is None
    # 64 experts divide 16 -> expert parallel
    spec = rules.pspec_for_shape((64, 256, 512),
                                 ("expert", "embed", "expert_mlp"))
    assert spec[0] == "model"
    assert spec[1] == "data"
    assert spec[2] is None          # model already used by experts


def test_rules_no_axis_reuse_within_tensor():
    rules = LogicalRules(_FakeMesh((16, 16), ("data", "model")))
    spec = rules.pspec_for_shape((1024, 1024), ("vocab", "mlp"))
    used = [e for e in spec if e is not None]
    assert len(set(used)) == len(used)


def test_rules_pod_axis_prefix():
    rules = LogicalRules(_FakeMesh((2, 16, 16), ("pod", "data", "model")))
    spec = rules.pspec_for_shape((256, 4096), ("batch", "seq"))
    assert spec[0] == ("pod", "data")


def test_kv_cache_sp_fallback():
    """kv_heads < model axis -> sequence-parallel cache sharding."""
    from repro.configs import get_config
    from repro.models.attention import kv_cache_axes
    from repro.models.common import sharding_ctx
    rules = LogicalRules(_FakeMesh((16, 16), ("data", "model")))
    with sharding_ctx(None, None):
        pass
    # simulate rules context
    from repro.models import common
    common._CTX["rules"] = rules
    try:
        ax = kv_cache_axes(get_config("deepseek-67b"))      # kv=8 < 16
        assert ax[1] == "kv_seq"
        ax = kv_cache_axes(get_config("stablelm-1.6b"))     # kv=32 % 16
        assert ax[2] == "kv_heads"
    finally:
        common._CTX["rules"] = None


# ---------------------------------------------------------------------------
# Checkpoint / restart / elastic
# ---------------------------------------------------------------------------

def test_checkpoint_roundtrip_and_gc():
    tree = {"a": jnp.arange(12.0).reshape(3, 4),
            "b": {"c": jnp.ones((2,), jnp.int32)}}
    with tempfile.TemporaryDirectory() as d:
        ck = Checkpointer(d, keep=2)
        for step in (1, 2, 3):
            ck.save(step, tree, meta={"step": step})
        assert ck.all_steps() == [2, 3]          # gc keeps 2
        restored, man = ck.restore(tree)
        for a, b in zip(jax.tree.leaves(restored), jax.tree.leaves(tree)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        assert man["step"] == 3


def test_checkpoint_detects_corruption():
    tree = {"w": jnp.ones((4, 4))}
    with tempfile.TemporaryDirectory() as d:
        ck = Checkpointer(d)
        path = ck.save(1, tree)
        victim = [f for f in os.listdir(path) if f.endswith(".npy")][0]
        with open(os.path.join(path, victim), "r+b") as f:
            f.seek(200)
            f.write(b"\xde\xad")
        with pytest.raises(IOError):
            ck.restore(tree)


def test_supervisor_recovers_from_crash():
    calls = {"n": 0}

    def step_fn(state, step):
        calls["n"] += 1
        if calls["n"] == 4:
            raise RuntimeError("injected")
        return {"x": state["x"] + 1}

    with tempfile.TemporaryDirectory() as d:
        sup = Supervisor(Checkpointer(d), checkpoint_every=2,
                         max_restarts=2)
        out = sup.run({"x": jnp.zeros(())}, step_fn, 0, 6)
        assert sup.restarts == 1
        assert float(out["x"]) == 6.0        # replay exactly, no skips


def test_heartbeat_straggler_detection():
    m = HeartbeatMonitor(window=8, straggler_factor=2.0)
    for i in range(8):
        m.record(0, 1.0)
        m.record(1, 1.1)
        m.record(2, 5.0)
    assert m.stragglers() == [2]


def test_elastic_mesh_shrink():
    assert elastic_mesh_shape(256, 16) == ((16, 16), ("data", "model"))
    # lose a host: 240 devices -> largest pow2 data axis is 8
    assert elastic_mesh_shape(240, 16) == ((8, 16), ("data", "model"))
    with pytest.raises(ValueError):
        elastic_mesh_shape(8, 16)


# ---------------------------------------------------------------------------
# Gradient compression
# ---------------------------------------------------------------------------

def test_ef_roundtrip_error_feedback_converges():
    """Accumulated error feedback keeps the SUM of compressed grads close
    to the sum of true grads (bias-free over steps)."""
    rng = np.random.default_rng(0)
    g_true = [jnp.asarray(rng.standard_normal((8, 16)), jnp.float32)
              for _ in range(50)]
    err = init_error_buffer(g_true[0])
    tot_c = jnp.zeros((8, 16))
    for g in g_true:
        c, err = ef_roundtrip(g, err)
        tot_c = tot_c + c
    tot = sum(g_true)
    # residual is bounded by one quantization step, not O(n_steps)
    resid = float(jnp.abs(tot_c - tot).max())
    assert resid < 0.05


def test_quantized_adam_state_memory():
    from repro.optim import adamw
    params = {"w": jnp.ones((64, 128))}
    opt = adamw(lr=1e-3, quantize_v=True)
    state = opt.init(params)
    q, scale = state.v["w"]
    assert q.dtype == jnp.int8
    g = {"w": jnp.full((64, 128), 0.01)}
    p2, s2 = opt.update(g, state, params)
    assert np.isfinite(np.asarray(p2["w"])).all()


# ---------------------------------------------------------------------------
# HLO stats parser
# ---------------------------------------------------------------------------

def test_hlo_stats_loop_weighting():
    from repro.launch.hlo_stats import HloStats
    hlo = """
HloModule test

%body (p: (s32[], f32[8,8])) -> (s32[], f32[8,8]) {
  %p = (s32[], f32[8,8]) parameter(0)
  %g = f32[8,8] get-tuple-element(%p), index=1
  %dot.1 = f32[8,8] dot(%g, %g), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %ar = f32[8,8] all-reduce(%dot.1), replica_groups=[4,8]<=[32], to_apply=%add
}

%cond (p: (s32[], f32[8,8])) -> pred[] {
  %c = s32[] constant(7)
  ROOT %lt = pred[] compare(%i, %c), direction=LT
}

ENTRY %main (a: f32[8,8]) -> f32[8,8] {
  %a = f32[8,8] parameter(0)
  %w = (s32[], f32[8,8]) while(%t), condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"7"}}
}
"""
    st = HloStats(hlo)
    # dot: 2*8*8*8 = 1024 flops x 7 iterations
    assert st.flops == 7 * 1024
    ar = st.collectives["all-reduce"]
    assert ar["count"] == 7
    # 8x8 f32 = 256B; all-reduce ici factor 2*(8-1)/8
    assert abs(ar["ici_bytes"] - 7 * 256 * 2 * 7 / 8) < 1e-6
