"""Live-ingestion subsystem tests (repro.stream).

The contracts under test:

  * **segment-append == one-shot** — ingesting a 48-frame clip as ANY
    tested sequence of segment appends (sizes {1, 7, 12, 48}) yields
    BIT-IDENTICAL rows, offsets, histograms, track bboxes, summaries
    and cost counters to a one-shot batch ingest, for dense and
    skip-heavy θ (sort and recurrent trackers, gap 1 and 2);
  * **incremental index merge == full rebuild** — at EVERY intermediate
    watermark, the incrementally merged index equals
    ``build_index``/``summarize`` run from scratch;
  * **checkpoint resume** — a brand-new ingestor (fresh store instance
    over the same root, as after a process restart) resumes mid-stream
    from the persisted ``TrackerCheckpoint`` and still seals
    bit-identically;
  * **standing queries** — accumulated deltas reconstruct the ad-hoc
    answer (``service.query`` AND the naive ``ref.reference_query``
    oracle) at every watermark, scanning each visible row at most
    once, with summary-skippable deltas dropped unscanned.
"""
import dataclasses

import numpy as np
import pytest

from repro.core import pipeline as pl
from repro.query import Query, QueryService, Region, StoreBudget, \
    TimeRange, TrackStore
from repro.query.index import build_index, summarize
from repro.query.ref import reference_query
from repro.stream import (SegmentIngestor, StandingQuery,
                          TrackerCheckpoint)

SEG_SIZES = (1, 7, 12, 48)


@pytest.fixture(scope="module")
def stream_sys(qsys):
    """48-frame clips + the two θ of the resume sweep, sharing qsys's
    trained bank (detector training dominates; build it once)."""
    bank, params, _, _, _ = qsys
    from repro.data.video_synth import make_split
    clips = make_split("caldot1", "stream", 2, n_frames=48)
    res = params.proxy_res
    W, H = params.det_res
    frame, _ = pl.render_frame(clips[0], 0, W, H)
    s, _ = bank.proxies[res].scores(pl._downsample(frame, res))
    dense = dataclasses.replace(
        params, proxy_threshold=float(np.quantile(s, 0.5)), gap=1,
        tracker="sort")
    skip_heavy = dataclasses.replace(
        params, proxy_threshold=float(np.quantile(s, 0.97)), gap=2,
        tracker="recurrent")
    return bank, {"dense": dense, "skip_heavy": skip_heavy}, clips


def _batch_packed(bank, params, clip, tmp_path, tag):
    store = TrackStore(str(tmp_path / f"batch_{tag}"), bank, params)
    store.ingest([clip])
    return store.get(clip)


def _assert_packed_equal(a, b):
    """Everything but the timing field, bit for bit."""
    np.testing.assert_array_equal(a.rows, b.rows)
    np.testing.assert_array_equal(a.offsets, b.offsets)
    np.testing.assert_array_equal(a.hist, b.hist)
    np.testing.assert_array_equal(a.track_bbox, b.track_bbox)
    assert a.summary == b.summary
    assert a.counters == b.counters
    assert a.n_frames == b.n_frames


def _assert_index_matches_rebuild(packed):
    hist, bbox = build_index(packed.rows, packed.offsets,
                             packed.n_frames)
    np.testing.assert_array_equal(packed.hist, hist)
    np.testing.assert_array_equal(packed.track_bbox, bbox)
    assert packed.summary == summarize(packed.rows, packed.offsets,
                                       hist, bbox)


# ---------------------------------------------------------------------------
# The tentpole property: segment-append == one-shot, for every split
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("theta", ["dense", "skip_heavy"])
@pytest.mark.parametrize("seg", SEG_SIZES)
def test_segment_append_bit_identical(stream_sys, tmp_path, theta, seg):
    bank, thetas, clips = stream_sys
    params = thetas[theta]
    clip = clips[0]
    ref = _batch_packed(bank, params, clip, tmp_path, f"{theta}_{seg}")
    live = TrackStore(str(tmp_path / f"live_{theta}_{seg}"), bank,
                      params)
    ing = SegmentIngestor(live)
    assert ing.open(clip) == 0
    total = 0
    while total < clip.n_frames:
        rep = ing.append(clip, seg)
        total = rep.watermark
        packed = live.get(clip)
        assert packed is not None
        # incremental index merge == full rebuild, EVERY watermark
        assert packed.n_frames == total
        _assert_index_matches_rebuild(packed)
        assert live.watermark(clip) == total
        assert (packed.watermark is None) == rep.sealed
    assert rep.sealed and total == clip.n_frames
    _assert_packed_equal(ref, live.get(clip))


def test_seal_convenience(stream_sys, tmp_path):
    bank, thetas, clips = stream_sys
    params = thetas["dense"]
    clip = clips[1]
    ref = _batch_packed(bank, params, clip, tmp_path, "seal")
    live = TrackStore(str(tmp_path / "live_seal"), bank, params)
    ing = SegmentIngestor(live)
    ing.open(clip)
    ing.append(clip, 20)
    _assert_packed_equal(ref, ing.seal(clip))   # appends the rest
    _assert_packed_equal(ref, ing.seal(clip))   # idempotent


def test_resume_across_ingestor_instances(stream_sys, tmp_path):
    """Process-restart path: a NEW store + ingestor over the same root
    resumes from the checkpoint sidecar (GRU state included) and the
    sealed clip is still bit-identical to the batch ingest."""
    bank, thetas, clips = stream_sys
    params = thetas["skip_heavy"]               # recurrent tracker
    clip = clips[0]
    ref = _batch_packed(bank, params, clip, tmp_path, "resume")
    root = str(tmp_path / "live_resume")
    first = SegmentIngestor(TrackStore(root, bank, params))
    first.open(clip)
    first.append(clip, 13)                      # mid-gap boundary
    # simulate process death: everything rebuilt from disk
    store2 = TrackStore(root, bank, params)
    second = SegmentIngestor(store2)
    assert second.open(clip) == 13
    second.append(clip, 13)
    second.append(clip, 48)                     # clamped, seals
    _assert_packed_equal(ref, store2.get(clip))


def test_device_tracker_ingest_and_resume(stream_sys, tmp_path):
    """Live ingestion under ExecutorOptions(device_tracker=True): the
    chunk-scan tracker seals bit-identically to the host batch ingest,
    and a checkpoint written under the device flavor resumes in a NEW
    ingestor running the HOST flavor (and vice versa) — the execution
    flavor is a scheduling knob, never part of the stream's state."""
    from repro.core.executor import ExecutorOptions
    bank, thetas, clips = stream_sys
    params = thetas["skip_heavy"]               # recurrent tracker
    clip = clips[0]
    ref = _batch_packed(bank, params, clip, tmp_path, "dev")
    dev_opts = ExecutorOptions(device_tracker=True)
    # whole-clip device ingest
    live = TrackStore(str(tmp_path / "live_dev"), bank, params)
    ing = SegmentIngestor(live, options=dev_opts)
    ing.open(clip)
    _assert_packed_equal(ref, ing.seal(clip))
    # device -> host resume across instances
    root = str(tmp_path / "live_dev_resume")
    first = SegmentIngestor(TrackStore(root, bank, params),
                            options=dev_opts)
    first.open(clip)
    first.append(clip, 13)                      # mid-gap boundary
    store2 = TrackStore(root, bank, params)
    second = SegmentIngestor(store2)            # host flavor
    assert second.open(clip) == 13
    second.append(clip, 48)                     # clamped, seals
    _assert_packed_equal(ref, store2.get(clip))
    # host -> device resume across instances
    root3 = str(tmp_path / "live_host_resume")
    h = SegmentIngestor(TrackStore(root3, bank, params))
    h.open(clip)
    h.append(clip, 13)
    store3 = TrackStore(root3, bank, params)
    third = SegmentIngestor(store3, options=dev_opts)
    assert third.open(clip) == 13
    third.append(clip, 48)
    _assert_packed_equal(ref, store3.get(clip))


def test_resume_rolls_back_to_stale_checkpoint(stream_sys, tmp_path):
    """checkpoint_every=2 leaves the store an append ahead of the
    sidecar (same state as a crash between materialize and checkpoint).
    Resume must ROLL BACK to the checkpoint and still seal
    bit-identically — re-appending rolled-back frames is
    deterministic."""
    bank, thetas, clips = stream_sys
    params = thetas["skip_heavy"]
    clip = clips[1]
    ref = _batch_packed(bank, params, clip, tmp_path, "rollback")
    root = str(tmp_path / "live_rollback")
    first = SegmentIngestor(TrackStore(root, bank, params),
                            checkpoint_every=2)
    first.open(clip)
    first.append(clip, 9)
    first.append(clip, 9)                       # checkpoint at 18
    first.append(clip, 9)                       # store at 27, ckpt at 18
    store2 = TrackStore(root, bank, params)
    second = SegmentIngestor(store2)
    assert second.open(clip) == 18              # rolled back
    assert store2.watermark(clip) == 18
    # the rolled-back store state matches a full rebuild
    _assert_index_matches_rebuild(store2.get(clip))
    while store2.watermark(clip) < clip.n_frames:
        second.append(clip, 9)
    _assert_packed_equal(ref, store2.get(clip))


def test_checkpoint_array_roundtrip(stream_sys, tmp_path):
    """to_arrays/from_arrays/save/load preserve tracker state exactly
    (ids, order, misses, boxes, GRU hidden, cursor)."""
    bank, thetas, clips = stream_sys
    params = thetas["skip_heavy"]
    live = TrackStore(str(tmp_path / "ckpt_rt"), bank, params)
    ing = SegmentIngestor(live, checkpoint_every=0)  # manual ckpts
    ing.open(clips[0])
    ing.append(clips[0], 17)
    path = ing.checkpoint(clips[0])
    ckpt = TrackerCheckpoint.load(path)
    rt = TrackerCheckpoint.from_arrays(ckpt.to_arrays())
    assert (rt.kind, rt.cursor, rt.watermark, rt.next_id,
            rt.last_frame) == (ckpt.kind, ckpt.cursor, ckpt.watermark,
                               ckpt.next_id, ckpt.last_frame)
    assert len(rt.active) == len(ckpt.active)
    assert len(rt.finished) == len(ckpt.finished)
    for a, b in zip(rt.finished + rt.active,
                    ckpt.finished + ckpt.active):
        assert a.track_id == b.track_id and a.misses == b.misses
        assert a.frames == b.frames
        np.testing.assert_array_equal(np.stack(a.boxes),
                                      np.stack(b.boxes))
        if ckpt.kind == "recurrent":
            np.testing.assert_array_equal(a.h, b.h)
    # restored trackers produce identical visible tracks
    t1 = ckpt.restore(bank, params).result()
    t2 = rt.restore(bank, params).result()
    assert len(t1) == len(t2)
    for x, y in zip(t1, t2):
        np.testing.assert_array_equal(x, y)


# ---------------------------------------------------------------------------
# Guard rails
# ---------------------------------------------------------------------------

def test_ingestor_rejects_refine(stream_sys, tmp_path):
    bank, thetas, _ = stream_sys
    params = dataclasses.replace(thetas["dense"], refine=True)
    store = TrackStore(str(tmp_path / "refine"), bank, params)
    with pytest.raises(ValueError, match="refine"):
        SegmentIngestor(store)


def test_open_requires_open_state(stream_sys, tmp_path):
    bank, thetas, clips = stream_sys
    params = thetas["dense"]
    store = TrackStore(str(tmp_path / "guards"), bank, params)
    ing = SegmentIngestor(store)
    with pytest.raises(KeyError):
        ing.append(clips[0], 8)                 # never opened
    store.ingest([clips[0]])                    # batch-sealed
    with pytest.raises(RuntimeError, match="fully materialized"):
        ing.open(clips[0])


def test_open_clip_never_evicted(stream_sys, tmp_path):
    """Budget pressure must not evict a mid-stream clip: its NPZ is the
    stream's only copy and a batch re-ingest would clobber the
    tracker/index state."""
    bank, thetas, clips = stream_sys
    params = thetas["dense"]
    store = TrackStore(str(tmp_path / "evict"), bank, params)
    ing = SegmentIngestor(store)
    ing.open(clips[0])
    ing.append(clips[0], 24)                    # open at watermark 24
    store.ingest([clips[1]])                    # sealed neighbor
    evicted = store.set_budget(StoreBudget(max_bytes=1))
    assert evicted == 1                         # only the sealed clip
    assert store.get(clips[0]) is not None      # open clip survives
    assert store.watermark(clips[0]) == 24
    store.set_budget(None)


# ---------------------------------------------------------------------------
# Standing queries
# ---------------------------------------------------------------------------

def _standing_queries(clips):
    return {
        "count": Query.count_frames(min_count=1),
        "region_frames": Query(
            (Region(0.0, 0.0, 1.0, 0.5),), aggregate="frames"),
        "count2": Query.count_frames(min_count=2),
        "duration": Query.duration(min_count=1),
        "tracks": Query.count_tracks(min_track_len=3),
        "windowed": Query.count_frames(
            min_count=1, time_range=TimeRange(10, 40)),
    }


def _reference(q, store, clips):
    plan_kw = {}
    from repro.query.plan import compile_query
    plan = compile_query(q)
    if plan.region is not None:
        plan_kw["region"] = (plan.region.x0, plan.region.y0,
                             plan.region.x1, plan.region.y1)
    if plan.time_range is not None:
        plan_kw["time_range"] = (plan.time_range.start,
                                 plan.time_range.end)
    return reference_query(
        [store.tracks(c) for c in clips],
        [c.profile.fps for c in clips],
        min_len=plan.min_len, min_count=plan.min_count,
        aggregate=q.aggregate, **plan_kw)


def test_standing_deltas_reconstruct_adhoc(stream_sys, tmp_path):
    """Acceptance: at EVERY watermark, each standing query's
    accumulated state equals the ad-hoc plan over the store AND the
    naive reference oracle — and no visible row is scanned twice."""
    bank, thetas, clips = stream_sys
    params = thetas["dense"]
    store = TrackStore(str(tmp_path / "standing"), bank, params)
    service = QueryService(store)
    ing = SegmentIngestor(store, service=service)
    sqs = {name: service.register_standing(StandingQuery(q, clips))
           for name, q in _standing_queries(clips).items()}
    for c in clips:
        ing.open(c)
    watermark = 0
    while watermark < clips[0].n_frames:
        for c in clips:                         # interleaved appends
            ing.append(c, 12)
        watermark += 12
        for name, q in _standing_queries(clips).items():
            acc = sqs[name].result()
            adhoc = service.query(q, clips)
            ref = _reference(q, store, clips)
            assert acc.aggregates == adhoc.aggregates \
                == ref["aggregates"], \
                (name, watermark, acc.aggregates, adhoc.aggregates)
            if q.aggregate == "frames":
                assert sorted(acc.frames) == adhoc.frames \
                    == ref["frames"], (name, watermark)
    # each visible row delivered exactly once across the stream
    total_rows = sum(len(store.get(c).rows) for c in clips)
    for name, sq in sqs.items():
        assert sq.rows_scanned <= total_rows, name
    assert sqs["count"].rows_scanned == total_rows


def test_standing_skip_unaffected_clips(stream_sys, tmp_path):
    """A region provably disjoint from everything: every delta is
    dropped via the summary (zero rows scanned) yet the accumulated
    answer still matches ad-hoc."""
    bank, thetas, clips = stream_sys
    params = thetas["dense"]
    store = TrackStore(str(tmp_path / "standing_skip"), bank, params)
    service = QueryService(store)
    ing = SegmentIngestor(store, service=service)
    q = Query.count_frames(region=(0.0, 0.0, 0.01, 0.01), min_count=1)
    sq = service.register_standing(StandingQuery(q, clips))
    ing.open(clips[0])
    for _ in range(4):
        ing.append(clips[0], 12)
    assert sq.rows_scanned == 0
    assert sq.clips_skipped >= 1
    assert sq.result().aggregates == \
        service.query(q, clips).aggregates


def test_standing_registration_midstream(stream_sys, tmp_path):
    """Registering after some appends bootstraps from the store and
    stays exact from there on."""
    bank, thetas, clips = stream_sys
    params = thetas["dense"]
    store = TrackStore(str(tmp_path / "standing_mid"), bank, params)
    service = QueryService(store)
    ing = SegmentIngestor(store, service=service)
    ing.open(clips[0])
    ing.append(clips[0], 24)                    # before registration
    q = Query.count_frames(min_count=1)
    sq = service.register_standing(StandingQuery(q, clips[:1]))
    assert sq.result().aggregates == \
        service.query(q, clips[:1]).aggregates
    ing.append(clips[0], 12)                    # after registration
    assert sq.result().aggregates == \
        service.query(q, clips[:1]).aggregates
    service.unregister_standing(sq)
    before = sq.result().aggregates
    ing.append(clips[0], 12)                    # no longer notified
    assert sq.result().aggregates == before


def test_standing_rejects_limit_and_classes(stream_sys):
    _, _, clips = stream_sys
    from repro.query import Limit, TrackFilter
    with pytest.raises(ValueError, match="Limit"):
        StandingQuery(Query((), limit=Limit(3)), clips)
    with pytest.raises(ValueError, match="class"):
        StandingQuery(Query((TrackFilter(classes=(0,)),),
                            aggregate="tracks"), clips)


def test_query_open_clip_midstream(stream_sys, tmp_path):
    """Ad-hoc queries over an open clip answer from the ingested
    prefix — indexed and scan paths agree with the oracle at every
    watermark."""
    bank, thetas, clips = stream_sys
    params = thetas["skip_heavy"]
    store = TrackStore(str(tmp_path / "midstream"), bank, params)
    service = QueryService(store)
    ing = SegmentIngestor(store)
    clip = clips[0]
    ing.open(clip)
    q = Query.count_frames(min_count=1)
    for _ in range(4):
        ing.append(clip, 12)
        indexed = service.query(q, [clip])
        scanned = service.query(q, [clip], use_index=False)
        assert indexed.aggregates == scanned.aggregates
        ref = _reference(q, store, [clip])
        assert indexed.aggregates["count"] == \
            ref["aggregates"]["count"]
