"""Equivalence tests for the staged chunked engine: identical tracks,
window counts, and counters vs the per-frame reference path, plus the
bucketed jit-specialization bound."""
import numpy as np
import pytest

from repro.configs.multiscope import MULTISCOPE_PIPELINE
from repro.core import pipeline as pl
from repro.core.detector import detect_jit_entries, next_bucket
from repro.core.engine import run_clip_chunked
from repro.core.proxy import ProxyModel
from repro.core.tracker import init_tracker
from repro.core.train_models import train_detector
from repro.data.video_synth import make_split


@pytest.fixture(scope="module")
def engine_bank():
    cfg = MULTISCOPE_PIPELINE.reduced()
    clips = make_split("caldot1", "train", 2, n_frames=24)
    det, _ = train_detector("ssd-lite", clips,
                            [cfg.detector.resolutions[-1]], steps=60)
    bank = pl.ModelBank(cfg, {"ssd-lite": det, "ssd-deep": det})
    res = cfg.proxy.resolutions[-1]
    proxy = ProxyModel(cfg.proxy.cell, cfg.proxy.base_channels, res)
    bank.proxies = {res: proxy}
    bank.sizes_cells = [pl.det_grid(cfg.detector.resolutions[-1]),
                        (3, 2), (5, 3)]
    bank.ref_grid = pl.det_grid(cfg.detector.resolutions[-1])
    bank.tracker_params = init_tracker(cfg.tracker)
    # a threshold just above the untrained proxy's score median makes the
    # positive-cell grid SPARSE, so planning emits real sub-frame windows
    W, H = cfg.detector.resolutions[-1]
    frame, _ = pl.render_frame(clips[0], 0, W, H)
    s, _ = proxy.scores(pl._downsample(frame, res))
    return bank, clips, res, float(np.quantile(s, 0.85))


def _assert_same(a, b):
    assert a.frames_processed == b.frames_processed
    assert a.detector_windows == b.detector_windows
    assert a.full_frames == b.full_frames
    assert a.skipped_frames == b.skipped_frames
    assert len(a.tracks) == len(b.tracks)
    for x, y in zip(a.tracks, b.tracks):
        np.testing.assert_array_equal(x, y)


@pytest.mark.parametrize("gap", [1, 4])
@pytest.mark.parametrize("proxy_on", [False, True])
def test_engine_equivalence(engine_bank, proxy_on, gap):
    bank, clips, res, th = engine_bank
    params = pl.PipelineParams(
        "ssd-lite", bank.cfg.detector.resolutions[-1], 0.4, gap=gap,
        proxy_res=res if proxy_on else None, proxy_threshold=th,
        tracker="sort", refine=False)
    for clip in clips:
        _assert_same(pl.run_clip_frames(bank, params, clip),
                     run_clip_chunked(bank, params, clip))


def test_engine_equivalence_recurrent(engine_bank):
    """The recurrent tracker path: chunk-batched crop embeddings must
    reproduce the per-frame path bit-exactly."""
    bank, clips, res, th = engine_bank
    params = pl.PipelineParams(
        "ssd-lite", bank.cfg.detector.resolutions[-1], 0.4, gap=1,
        proxy_res=res, proxy_threshold=th, tracker="recurrent",
        refine=False)
    for clip in clips:
        _assert_same(pl.run_clip_frames(bank, params, clip),
                     run_clip_chunked(bank, params, clip))


def test_engine_skip_and_full_fallback(engine_bank):
    """Degenerate proxies: impossible threshold skips every frame;
    negative threshold falls back to full frames — on both engines."""
    bank, clips, res, _ = engine_bank
    base = pl.PipelineParams(
        "ssd-lite", bank.cfg.detector.resolutions[-1], 0.4, gap=2,
        proxy_res=res, proxy_threshold=0.9999999, tracker="sort",
        refine=False)
    a = run_clip_chunked(bank, base, clips[0])
    assert a.skipped_frames == a.frames_processed
    _assert_same(pl.run_clip_frames(bank, base, clips[0]), a)
    import dataclasses
    low = dataclasses.replace(base, proxy_threshold=-0.1)
    b = run_clip_chunked(bank, low, clips[0])
    assert b.skipped_frames == 0 and b.full_frames == b.frames_processed
    _assert_same(pl.run_clip_frames(bank, low, clips[0]), b)


def test_engine_run_clip_dispatch(engine_bank):
    """pipeline.run_clip routes to the chunked engine by default and to
    the reference path with engine="frame"."""
    bank, clips, res, th = engine_bank
    params = pl.PipelineParams(
        "ssd-lite", bank.cfg.detector.resolutions[-1], 0.4, gap=2,
        proxy_res=res, proxy_threshold=th, tracker="sort", refine=False)
    _assert_same(pl.run_clip(bank, params, clips[0]),
                 pl.run_clip(bank, params, clips[0], engine="frame"))


def test_jit_specializations_bounded(engine_bank):
    """Bucketed batching keeps detector jit entries fixed across inputs:
    a second clip adds NO new specializations."""
    bank, clips, res, th = engine_bank
    params = pl.PipelineParams(
        "ssd-lite", bank.cfg.detector.resolutions[-1], 0.4, gap=1,
        proxy_res=res, proxy_threshold=th, tracker="sort", refine=False)
    for clip in clips:
        run_clip_chunked(bank, params, clip)
    before = detect_jit_entries()
    for clip in clips:
        run_clip_chunked(bank, params, clip)
    assert detect_jit_entries() == before
    # every specialization is one (size class, power-of-two bucket):
    # sizes * buckets (+1 warmup batch) bounds the cache size
    n_sizes = len(pl.make_sizeset(bank, params).sizes)
    import math
    n_buckets = int(math.log2(next_bucket(
        bank.cfg.windows.max_windows * 16))) + 1
    assert before <= n_sizes * n_buckets + 2


def test_next_bucket():
    assert [next_bucket(n) for n in (1, 2, 3, 4, 5, 8, 9, 17)] == \
        [1, 2, 4, 4, 8, 8, 16, 32]
    assert next_bucket(3, min_bucket=8) == 8
