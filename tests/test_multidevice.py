"""Multi-device chunk round-robin under a forced 4-device host platform.

The executor's device-assignment path (``_RunContext.device_for`` +
per-chunk ``jax.device_put``) was previously exercised only at world
size 1.  ``XLA_FLAGS=--xla_force_host_platform_device_count=4`` must be
set before jax initializes, so the scenario runs in a subprocess with a
clean interpreter: it asserts 4 devices are visible, that chunks
actually round-robin ALL of them (sub-frame window gathers force the
padded device buffers into use), and that tracks match the per-frame
reference under both schedulers.

Note on tolerance: forced host-platform devices PARTITION XLA's
intra-op threadpool, so a convolution dispatched to device 2 may split
its reductions differently than the same convolution on device 0 —
last-ulp differences in box coordinates between devices are expected
(bit-identity holds per device; world-size-1 CI keeps asserting it
exactly).  Track STRUCTURE (count, frames, ids) and the RunResult
counters must still match exactly; boxes are compared at float32
tolerance.
"""
import os
import subprocess
import sys

_REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

_SCRIPT = r"""
import os
assert "xla_force_host_platform_device_count=4" in \
    os.environ.get("XLA_FLAGS", "")
import numpy as np
import jax

assert jax.device_count() == 4, jax.devices()

from repro.configs.multiscope import MULTISCOPE_PIPELINE
from repro.core import pipeline as pl
from repro.core.executor import (ClipExecutor, ExecutorOptions,
                                 run_clip_streamed)
from repro.core.proxy import ProxyModel
from repro.core.tracker import init_tracker
from repro.core.train_models import train_detector
from repro.data.video_synth import make_split

cfg = MULTISCOPE_PIPELINE.reduced()
clips = make_split("caldot1", "train", 1, n_frames=16)
det, _ = train_detector("ssd-lite", clips,
                        [cfg.detector.resolutions[-1]], steps=40)
bank = pl.ModelBank(cfg, {"ssd-lite": det, "ssd-deep": det})
res = cfg.proxy.resolutions[-1]
proxy = ProxyModel(cfg.proxy.cell, cfg.proxy.base_channels, res)
bank.proxies = {res: proxy}
bank.sizes_cells = [pl.det_grid(cfg.detector.resolutions[-1]),
                    (3, 2), (5, 3)]
bank.ref_grid = pl.det_grid(cfg.detector.resolutions[-1])
bank.tracker_params = init_tracker(cfg.tracker)
W, H = cfg.detector.resolutions[-1]
frame, _ = pl.render_frame(clips[0], 0, W, H)
s, _ = proxy.scores(pl._downsample(frame, res))
# sparse positive grid -> real sub-frame windows -> device uploads
params = pl.PipelineParams(
    "ssd-lite", cfg.detector.resolutions[-1], 0.4, gap=1,
    proxy_res=res, proxy_threshold=float(np.quantile(s, 0.85)),
    tracker="sort", refine=False, chunk_size=4)

clip = clips[0]
ref = pl.run_clip_frames(bank, params, clip)

# the default device list is all 4 forced host devices, and the 4
# chunks of a 16-frame clip at B=4 round-robin every one of them
ex = ClipExecutor(bank, params, ExecutorOptions(prefetch=False))
run = ex.start(clip)
assert len(run.ctx.devices) == 4, run.ctx.devices
tasks = ex._tasks(run.ctx)
assert len(tasks) == 4
assigned = {run.ctx.device_for(t).id for t in tasks}
assert assigned == {0, 1, 2, 3}, assigned
seq = ex.finish(run)

stream = run_clip_streamed(bank, params, clip,
                           ExecutorOptions(decode_workers=2))

for r in (seq, stream):
    assert r.frames_processed == ref.frames_processed
    assert r.detector_windows == ref.detector_windows
    assert r.full_frames == ref.full_frames
    assert r.skipped_frames == ref.skipped_frames
    assert len(r.tracks) == len(ref.tracks)
    for a, b in zip(ref.tracks, r.tracks):
        # structure exact; boxes to fp32 tolerance (cross-device
        # reduction-order divergence, see module docstring)
        assert a.shape == b.shape
        np.testing.assert_array_equal(a[:, 0], b[:, 0])   # frames
        np.testing.assert_array_equal(a[:, 5], b[:, 5])   # track ids
        np.testing.assert_allclose(a[:, 1:5], b[:, 1:5],
                                   rtol=0, atol=1e-6)

# a per-clip device offset rotates the assignment (run_clips' stagger)
run2 = ex.start(clip, device_offset=1)
assert run2.ctx.device_for(tasks[0]).id == 1
ex.cancel(run2)
print("MULTIDEVICE-OK")
"""


def test_chunk_round_robin_across_four_devices():
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["PYTHONPATH"] = os.path.join(_REPO, "src") + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    proc = subprocess.run([sys.executable, "-c", _SCRIPT], cwd=_REPO,
                          env=env, capture_output=True, text=True,
                          timeout=540)
    assert proc.returncode == 0, \
        f"stdout:\n{proc.stdout}\nstderr:\n{proc.stderr}"
    assert "MULTIDEVICE-OK" in proc.stdout
