"""Per-kernel validation: Pallas (interpret=True) and the jnp fallback vs
the pure-jnp oracle, swept over shapes and dtypes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention.kernel import flash_attention_pallas
from repro.kernels.flash_attention.ops import _chunked_jnp, flash_attention
from repro.kernels.flash_attention.ref import flash_attention_ref
from repro.kernels.decode_attention.kernel import decode_attention_pallas
from repro.kernels.decode_attention.ops import _jnp_fallback
from repro.kernels.decode_attention.ref import decode_attention_ref
from repro.kernels.ssd_scan.kernel import ssd_scan_pallas
from repro.kernels.ssd_scan.ops import _chunked_jnp as ssd_chunked
from repro.kernels.ssd_scan.ops import ssd_scan, ssd_step
from repro.kernels.ssd_scan.ref import ssd_scan_ref
from repro.kernels.proxy_score.kernel import proxy_score_pallas
from repro.kernels.proxy_score.ref import proxy_score_ref
from repro.kernels.window_gather.kernel import window_gather_pallas
from repro.kernels.window_gather.ref import window_gather_ref

TOL = {jnp.float32: 2e-5, jnp.bfloat16: 2e-2}


def _rand(key, shape, dtype):
    return jax.random.normal(key, shape, jnp.float32).astype(dtype)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,Sq,Skv,Hq,Hkv,D,causal", [
    (2, 128, 128, 4, 2, 64, True),
    (1, 64, 256, 4, 4, 32, True),
    (2, 128, 128, 8, 2, 64, False),
    (1, 64, 64, 2, 1, 128, True),
])
def test_flash_attention(dtype, B, Sq, Skv, Hq, Hkv, D, causal):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = _rand(ks[0], (B, Sq, Hq, D), dtype)
    k = _rand(ks[1], (B, Skv, Hkv, D), dtype)
    v = _rand(ks[2], (B, Skv, Hkv, D), dtype)
    ref = flash_attention_ref(q, k, v, causal=causal)
    chk = _chunked_jnp(q, k, v, causal=causal, sm_scale=1.0 / D ** 0.5,
                       block_k=64)
    pal = flash_attention_pallas(q, k, v, causal=causal, block_q=64,
                                 block_k=64, interpret=True)
    tol = TOL[dtype]
    np.testing.assert_allclose(np.asarray(chk, np.float32),
                               np.asarray(ref, np.float32), atol=tol,
                               rtol=tol)
    np.testing.assert_allclose(np.asarray(pal, np.float32),
                               np.asarray(ref, np.float32), atol=tol,
                               rtol=tol)


def test_flash_attention_ragged_noncausal():
    """Whisper-style cross attention: Skv not a block multiple."""
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = _rand(ks[0], (2, 100, 4, 32), jnp.float32)
    k = _rand(ks[1], (2, 75, 2, 32), jnp.float32)
    v = _rand(ks[2], (2, 75, 2, 32), jnp.float32)
    ref = flash_attention_ref(q, k, v, causal=False)
    got = flash_attention(q, k, v, causal=False, block_q=64, block_k=64)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,S,Hq,Hkv,D,bk", [
    (2, 256, 8, 2, 64, 64),
    (3, 128, 4, 4, 32, 128),
    (1, 512, 16, 8, 128, 256),
])
def test_decode_attention(dtype, B, S, Hq, Hkv, D, bk):
    ks = jax.random.split(jax.random.PRNGKey(2), 4)
    q = _rand(ks[0], (B, Hq, D), dtype)
    k = _rand(ks[1], (B, S, Hkv, D), dtype)
    v = _rand(ks[2], (B, S, Hkv, D), dtype)
    kvlen = jax.random.randint(ks[3], (B,), 1, S + 1)
    ref = decode_attention_ref(q, k, v, kvlen)
    fb = _jnp_fallback(q, k, v, kvlen, sm_scale=1.0 / D ** 0.5)
    pal = decode_attention_pallas(q, k, v, kvlen, block_k=bk,
                                  interpret=True)
    tol = TOL[dtype]
    np.testing.assert_allclose(np.asarray(fb, np.float32),
                               np.asarray(ref, np.float32), atol=tol,
                               rtol=tol)
    np.testing.assert_allclose(np.asarray(pal, np.float32),
                               np.asarray(ref, np.float32), atol=tol,
                               rtol=tol)


@pytest.mark.parametrize("b,S,H,P,N,Q", [
    (2, 64, 4, 16, 8, 16),
    (1, 128, 2, 32, 16, 32),
    (2, 96, 3, 8, 8, 32),
])
def test_ssd_scan(b, S, H, P, N, Q):
    ks = jax.random.split(jax.random.PRNGKey(3), 6)
    x = jax.random.normal(ks[0], (b, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, S, H))) * 0.5
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.3)
    B = jax.random.normal(ks[3], (b, S, N)) * 0.5
    C = jax.random.normal(ks[4], (b, S, N)) * 0.5
    D = jax.random.normal(ks[5], (H,)) * 0.1
    yr, sr = ssd_scan_ref(x, dt, A, B, C, D)
    yc, sc = ssd_chunked(x, dt, A, B, C, D, Q)
    yp, sp = ssd_scan_pallas(x, dt, A, B, C, D, chunk=Q, interpret=True)
    np.testing.assert_allclose(np.asarray(yc), np.asarray(yr), atol=1e-4)
    np.testing.assert_allclose(np.asarray(yp), np.asarray(yr), atol=1e-4)
    np.testing.assert_allclose(np.asarray(sc), np.asarray(sr), atol=1e-4)
    np.testing.assert_allclose(np.asarray(sp), np.asarray(sr), atol=1e-4)


def test_ssd_decode_step_consistency():
    """scan(S) then one ssd_step == scan(S+1) exactly."""
    b, S, H, P, N = 1, 32, 2, 8, 8
    ks = jax.random.split(jax.random.PRNGKey(4), 6)
    x = jax.random.normal(ks[0], (b, S + 1, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, S + 1, H))) * 0.5
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.3)
    B = jax.random.normal(ks[3], (b, S + 1, N)) * 0.5
    C = jax.random.normal(ks[4], (b, S + 1, N)) * 0.5
    D = jax.random.normal(ks[5], (H,)) * 0.1
    y_full, s_full = ssd_scan_ref(x, dt, A, B, C, D)
    _, s_pre = ssd_scan_ref(x[:, :S], dt[:, :S], A, B[:, :S], C[:, :S], D)
    y1, s1 = ssd_step(s_pre, x[:, S], dt[:, S], A, B[:, S], C[:, S], D)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y_full[:, S]),
                               atol=1e-5)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s_full),
                               atol=1e-5)


def test_ssd_non_multiple_padding():
    """ssd_scan pads S to a chunk multiple exactly (dt=0 padding)."""
    b, S, H, P, N = 1, 25, 2, 8, 8
    ks = jax.random.split(jax.random.PRNGKey(5), 6)
    x = jax.random.normal(ks[0], (b, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, S, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.3)
    B = jax.random.normal(ks[3], (b, S, N)) * 0.5
    C = jax.random.normal(ks[4], (b, S, N)) * 0.5
    D = jax.random.normal(ks[5], (H,)) * 0.1
    yr, sr = ssd_scan_ref(x, dt, A, B, C, D)
    yo, so = ssd_scan(x, dt, A, B, C, D, chunk=16)
    np.testing.assert_allclose(np.asarray(yo), np.asarray(yr), atol=1e-4)
    np.testing.assert_allclose(np.asarray(so), np.asarray(sr), atol=1e-4)


@pytest.mark.parametrize("B,Hc,Wc,C", [(2, 7, 13, 32), (1, 4, 4, 16),
                                       (3, 8, 8, 64)])
def test_proxy_score(B, Hc, Wc, C):
    ks = jax.random.split(jax.random.PRNGKey(6), 2)
    feat = jax.random.normal(ks[0], (B, Hc, Wc, C))
    w = jax.random.normal(ks[1], (C,))
    sr, pr = proxy_score_ref(feat, w, 0.3, 0.5)
    sp, pp = proxy_score_pallas(feat, w, 0.3, 0.5, block_m=32,
                                interpret=True)
    np.testing.assert_allclose(np.asarray(sp), np.asarray(sr), atol=1e-6)
    assert (np.asarray(pp) == np.asarray(pr)).all()


@pytest.mark.parametrize("wh,ww", [(64, 96), (32, 32), (96, 64)])
def test_window_gather(wh, ww):
    frame = jax.random.normal(jax.random.PRNGKey(7), (160, 256, 3))
    oc = jnp.array([[0, 0], [1, 2], [2, 3]], jnp.int32)
    max_cy = (160 - wh) // 32
    max_cx = (256 - ww) // 32
    oc = jnp.minimum(oc, jnp.array([max_cy, max_cx]))
    ref = window_gather_ref(frame, oc * 32, win_h=wh, win_w=ww)
    pal = window_gather_pallas(frame, oc, win_h=wh, win_w=ww,
                               interpret=True)
    np.testing.assert_allclose(np.asarray(pal), np.asarray(ref))
    # numpy-crop oracle: the kernel must be a pure copy of the slices
    f = np.asarray(frame)
    for k, (cy, cx) in enumerate(np.asarray(oc)):
        crop = f[cy * 32:cy * 32 + wh, cx * 32:cx * 32 + ww]
        np.testing.assert_array_equal(np.asarray(pal)[k], crop)


@pytest.mark.parametrize("wh,ww", [(64, 96), (32, 32), (96, 64)])
def test_window_gather_batch(wh, ww):
    """Cross-frame gather (the chunked engine's hot path): Pallas
    interpret=True vs the jnp oracle vs direct numpy crops."""
    from repro.kernels.window_gather.kernel import (
        window_gather_batch_pallas)
    from repro.kernels.window_gather.ref import window_gather_batch_ref
    frames = jax.random.normal(jax.random.PRNGKey(8), (3, 160, 256, 3))
    tbl = jnp.array([[0, 0, 0], [2, 1, 2], [1, 2, 3], [2, 0, 1]],
                    jnp.int32)
    max_cy = (160 - wh) // 32
    max_cx = (256 - ww) // 32
    tbl = jnp.minimum(tbl, jnp.array([2, max_cy, max_cx]))
    ref = window_gather_batch_ref(
        frames, tbl * jnp.array([1, 32, 32], jnp.int32),
        win_h=wh, win_w=ww)
    pal = window_gather_batch_pallas(frames, tbl, win_h=wh, win_w=ww,
                                     interpret=True)
    np.testing.assert_allclose(np.asarray(pal), np.asarray(ref))
    f = np.asarray(frames)
    for k, (b, cy, cx) in enumerate(np.asarray(tbl)):
        crop = f[b, cy * 32:cy * 32 + wh, cx * 32:cx * 32 + ww]
        np.testing.assert_array_equal(np.asarray(pal)[k], crop)


@pytest.mark.parametrize("B,hp,wp,C,hc,wc", [
    (2, 20, 32, 16, 5, 8),     # clean downscale
    (1, 18, 30, 32, 5, 8),     # ragged spans
    (3, 6, 8, 16, 9, 11),      # upscale (hc > hp)
    (2, 12, 12, 8, 12, 12),    # identity mapping
])
def test_proxy_plan(B, hp, wp, C, hc, wc):
    """Fused plan kernel: Pallas interpret=True vs jnp ref vs the host
    map_proxy_grid path — mapped grids must be BIT-identical (the plan
    fast paths depend on it), stats must match a direct reduction."""
    from repro.core.pipeline import map_proxy_grid
    from repro.kernels.proxy_plan.kernel import proxy_plan_pallas
    from repro.kernels.proxy_plan.ops import span_matrix
    from repro.kernels.proxy_plan.ref import proxy_plan_ref
    ks = jax.random.split(jax.random.PRNGKey(9), 2)
    feat = jax.random.normal(ks[0], (B, hp, wp, C))
    w = jax.random.normal(ks[1], (C,)) * 0.5
    b, th = 0.1, 0.5
    sy = jnp.asarray(span_matrix(hc, hp))
    sx = jnp.asarray(span_matrix(wc, wp))
    gr, sr = proxy_plan_ref(feat, w, b, th, sy, sx)
    gp, sp = proxy_plan_pallas(feat, w, b, th, sy, sx, interpret=True)
    np.testing.assert_array_equal(np.asarray(gp), np.asarray(gr))
    np.testing.assert_array_equal(np.asarray(sp), np.asarray(sr))
    # host oracle: threshold the scores on the host, map with the
    # integral-image path, reduce with numpy
    logits = np.einsum("bhwc,c->bhw", np.asarray(feat, np.float64),
                       np.asarray(w, np.float64)) + b
    pos = (1.0 / (1.0 + np.exp(-logits)) > th).astype(np.int8)
    for k in range(B):
        host = map_proxy_grid(pos[k], (wc, hc))
        got = np.asarray(gr[k])
        np.testing.assert_array_equal(got, host.astype(np.int8))
        cnt = int(host.sum())
        assert int(sr[k, 0]) == cnt
        if cnt:
            ys, xs = np.nonzero(host)
            assert tuple(np.asarray(sr[k, 1:5])) == (
                ys.min(), ys.max(), xs.min(), xs.max())
        else:
            assert tuple(np.asarray(sr[k, 1:5])) == (hc, -1, wc, -1)


@pytest.mark.parametrize("K,N", [(1, 1), (3, 4), (2, 9), (4, 16)])
def test_assign(K, N):
    """Batched JV: Pallas interpret=True vs the vmapped-jnp fallback vs
    the host _hungarian_np oracle.  Costs are quantized to multiples of
    1/64 so f32 potential arithmetic is exact and even the first-index
    tie-breaking must agree across all three."""
    from repro.kernels.assign.kernel import assign_pallas
    from repro.kernels.assign.ops import _solve_vmapped
    from repro.kernels.assign.ref import assign_ref
    rng = np.random.default_rng(10 * K + N)
    costs = rng.integers(0, 256, (K, N, N)).astype(np.float32) / 64.0
    ref = assign_ref(costs)
    fb = np.asarray(_solve_vmapped(jnp.asarray(costs)))
    pal = np.asarray(assign_pallas(jnp.asarray(costs), interpret=True))
    np.testing.assert_array_equal(fb, ref)
    np.testing.assert_array_equal(pal, ref)
    # each row a permutation with minimal total (vs scipy when present)
    scipy_opt = pytest.importorskip("scipy.optimize")
    for k in range(K):
        assert sorted(ref[k]) == list(range(N))
        r, c = scipy_opt.linear_sum_assignment(costs[k])
        np.testing.assert_allclose(
            costs[k][np.arange(N), ref[k]].sum(), costs[k][r, c].sum())


def _track_step_operands(rng, K, Q, H, e, M):
    """Random track-step operands honoring the slot contract: live
    tracks and valid detections are PREFIXES, te gaps are integers,
    boxes live in roughly world units."""
    def g(*s):
        return rng.standard_normal(s).astype(np.float32)

    params = {
        "det_proj/w": g(e + 6, e) * 0.5, "det_proj/b": g(e) * 0.1,
        "gru/wz": g(e + H, H) * 0.5, "gru/wr": g(e + H, H) * 0.5,
        "gru/wh": g(e + H, H) * 0.5,
        "gru/bz": g(H) * 0.1, "gru/br": g(H) * 0.1, "gru/bh": g(H) * 0.1,
        "match/w0": g(H + e + 6, M) * 0.5, "match/b0": g(M) * 0.1,
        "match/w1": g(M, 1) * 0.5, "match/b1": g(1) * 0.1,
    }
    h_r = np.zeros((K, Q, H), np.float32)
    tbox_r = np.zeros((K, Q, 4), np.float32)
    alive_r = np.zeros((K, Q), np.float32)
    te_gap_r = np.zeros((K, Q), np.float32)
    te_match = np.zeros((K, Q), np.float32)
    x = np.zeros((K, Q, e), np.float32)
    dbox = np.zeros((K, Q, 4), np.float32)
    dvalid = np.zeros((K, Q), np.float32)
    for k in range(K):
        T = int(rng.integers(0, Q + 1))
        n = int(rng.integers(0, Q + 1))
        h_r[k, :T] = g(T, H) * 0.5
        tbox_r[k, :T] = rng.random((T, 4), np.float32)
        alive_r[k, :T] = 1.0
        te_gap_r[k, :T] = rng.integers(1, 9, T)
        te_match[k] = float(rng.integers(0, 9))
        x[k, :n] = g(n, e) * 0.5
        dbox[k, :n] = rng.random((n, 4), np.float32)
        dvalid[k, :n] = 1.0
    thr = np.full((1, 1), 0.35, np.float32)
    return (h_r, tbox_r, alive_r, te_gap_r, te_match, x, dbox,
            dvalid), thr, params


@pytest.mark.parametrize("K,Q,H,e,M", [(1, 8, 16, 8, 16),
                                       (2, 16, 24, 16, 24),
                                       (3, 8, 20, 12, 20)])
def test_track_step(K, Q, H, e, M):
    """Fused tracker step: Pallas interpret=True vs the vmapped-jnp
    fallback vs the numpy oracle, BIT-exact (the fastmath contract),
    plus the matched-column semantics (unique real columns, forbidden
    pairs reported -1)."""
    from repro.kernels.track_step import pack_params, track_step_ref
    from repro.kernels.track_step.kernel import track_step_pallas
    from repro.kernels.track_step.ops import LOG1P_TABLE_2D, _step_vmapped
    rng = np.random.default_rng(1000 * K + Q + H + e)
    arrs, thr, np_params = _track_step_operands(rng, K, Q, H, e, M)
    packed = pack_params(np_params)
    ref = track_step_ref(*arrs, thr, packed, LOG1P_TABLE_2D)
    fb = _step_vmapped(*[jnp.asarray(a) for a in arrs],
                       jnp.asarray(thr), *packed, LOG1P_TABLE_2D[:, 0])
    pal = track_step_pallas(*[jnp.asarray(a) for a in arrs],
                            jnp.asarray(thr), packed, LOG1P_TABLE_2D,
                            interpret=True)
    for r, f, p in zip(ref, fb, pal):
        np.testing.assert_array_equal(np.asarray(f), r)
        np.testing.assert_array_equal(np.asarray(p), r)
    matched = ref[0]
    alive, dvalid = arrs[2], arrs[7]
    for k in range(K):
        cols = matched[k][matched[k] >= 0]
        assert len(set(cols.tolist())) == len(cols)       # no col reuse
        assert np.all(dvalid[k][cols] > 0)                # real dets only
        assert np.all(matched[k][alive[k] <= 0] == -1)    # dead rows
