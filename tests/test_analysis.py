"""Self-tests for the ``repro.analysis`` contract linter: one seeded
known-bad fixture per pass (each must be caught at the right file and
line), suppression-comment mechanics, a fully clean fixture tree, and
the real tree itself shipping lint-clean.  Also functional regression
coverage for the three metrics races the lock-discipline pass found
when it first ran (Counter.value, Histogram.summary min/max,
RunProfile.dispatches)."""
import math
import textwrap
from pathlib import Path

import pytest

from repro.analysis import PASSES, Project, run_passes

REPO_ROOT = Path(__file__).resolve().parents[1]


def make_project(root: Path, files: dict) -> Project:
    """Materialize ``{rel: source}`` under ``root`` (repo shape:
    src/repro + benchmarks) and scan it."""
    for rel, text in files.items():
        p = root / rel
        p.parent.mkdir(parents=True, exist_ok=True)
        p.write_text(textwrap.dedent(text))
    return Project(root)


def active(report, pass_id=None):
    out = [f for f in report.findings if not f.suppressed]
    if pass_id is not None:
        out = [f for f in out if f.pass_id == pass_id]
    return out


# a README whose tables cover everything the clean fixtures emit
OBS_README = """\
    # obs naming

    | span | meaning |
    | --- | --- |
    | `run.clip` | one executor run |

    | metric | meaning |
    | --- | --- |
    | `executor.dispatches` | detector dispatch count |
"""


def test_registry_has_all_passes():
    assert set(PASSES) == {"bit-contract", "kernel-contract",
                           "lock-discipline", "obs-naming",
                           "tracked-bytecode"}


# -- seeded-bad fixture per pass ----------------------------------------------


def test_bit_contract_catches_raw_tanh_in_tracker(tmp_path):
    proj = make_project(tmp_path, {
        "src/repro/core/tracker.py": """\
            import jax.numpy as jnp

            def gru(x):
                return jnp.tanh(x)
        """,
    })
    rep = run_passes(proj, select=["bit-contract"])
    hits = active(rep, "bit-contract")
    assert len(hits) == 1
    f = hits[0]
    assert f.path == "src/repro/core/tracker.py"
    assert f.line == 4
    assert "jnp.tanh" in f.message and "fastmath" in f.message


def test_bit_contract_scopes_by_fastmath_import(tmp_path):
    # same call: flagged in a fastmath importer, ignored elsewhere
    body = """\
        import jax.numpy as jnp
        {imp}

        def f(x):
            return jnp.exp(x)
    """
    proj = make_project(tmp_path, {
        "src/repro/query/uses.py":
            body.format(imp="from repro.core import fastmath"),
        "src/repro/query/free.py": body.format(imp=""),
    })
    hits = active(run_passes(proj, select=["bit-contract"]))
    assert [f.path for f in hits] == ["src/repro/query/uses.py"]


def test_bit_contract_catches_negative_drop_scatter(tmp_path):
    proj = make_project(tmp_path, {
        "src/repro/kernels/assign/helper.py": """\
            def scatter(buf, vals):
                idx = -1
                return buf.at[idx].set(vals, mode="drop")
        """,
    })
    hits = active(run_passes(proj, select=["bit-contract"]))
    assert len(hits) == 1
    assert hits[0].line == 3
    assert "drop" in hits[0].message


def test_kernel_contract_catches_missing_ref_twin(tmp_path):
    proj = make_project(tmp_path, {
        "src/repro/kernels/foo/__init__.py": "",
        "src/repro/kernels/foo/kernel.py": """\
            def foo_pallas(x, y, *, interpret=False):
                return x
        """,
        "src/repro/kernels/foo/ops.py": "def foo(x, y): return x\n",
        "src/repro/kernels/foo/smoke.py": "def smoke(): pass\n",
    })
    hits = active(run_passes(proj, select=["kernel-contract"]))
    # missing ref.py file + foo_pallas lacking its foo_ref twin
    assert {f.path for f in hits} == {"src/repro/kernels/foo/kernel.py"}
    msgs = sorted(f.message for f in hits)
    assert any("ref.py" in m for m in msgs)
    assert any(f.line == 1 for f in hits)


def test_kernel_contract_catches_signature_mismatch(tmp_path):
    proj = make_project(tmp_path, {
        "src/repro/kernels/foo/__init__.py": "",
        "src/repro/kernels/foo/kernel.py": """\
            def foo_pallas(frame, origins, *, interpret=False):
                return frame
        """,
        "src/repro/kernels/foo/ops.py": "def foo(f, o): return f\n",
        "src/repro/kernels/foo/ref.py": """\
            def foo_ref(frame, centers):
                return frame
        """,
        "src/repro/kernels/foo/smoke.py": "def smoke(): pass\n",
    })
    hits = active(run_passes(proj, select=["kernel-contract"]))
    assert len(hits) == 1
    f = hits[0]
    assert f.path == "src/repro/kernels/foo/ref.py" and f.line == 1
    assert "positional parameters must agree" in f.message


def test_kernel_contract_catches_missing_interpret(tmp_path):
    proj = make_project(tmp_path, {
        "src/repro/kernels/foo/__init__.py": "",
        "src/repro/kernels/foo/kernel.py": """\
            def foo_pallas(x):
                return x
        """,
        "src/repro/kernels/foo/ops.py": "def foo(x): return x\n",
        "src/repro/kernels/foo/ref.py": "def foo_ref(x): return x\n",
        "src/repro/kernels/foo/smoke.py": "def smoke(): pass\n",
    })
    hits = active(run_passes(proj, select=["kernel-contract"]))
    assert any("interpret" in f.message and f.line == 1 for f in hits)


LOCKED_COUNTER = """\
    import threading

    class Box:
        def __init__(self):
            self._lock = threading.Lock()
            self._n = 0  # guarded-by: _lock

        def inc(self):
            with self._lock:
                self._n += 1

        def peek(self):
            return self._n
"""


def test_lock_discipline_catches_unguarded_read(tmp_path):
    proj = make_project(
        tmp_path, {"src/repro/obs/box.py": LOCKED_COUNTER})
    hits = active(run_passes(proj, select=["lock-discipline"]))
    assert len(hits) == 1
    f = hits[0]
    assert f.path == "src/repro/obs/box.py" and f.line == 13
    assert "Box._n" in f.message and "_lock" in f.message


def test_lock_discipline_regression_histogram_summary_shape(tmp_path):
    # the exact shape of the pre-PR-9 Histogram.summary() bug this
    # pass caught in obs/metrics.py: count snapshotted under the
    # lock, min/max read again after releasing it
    proj = make_project(tmp_path, {"src/repro/obs/hist.py": """\
        import threading

        class Hist:
            def __init__(self):
                self._lock = threading.Lock()
                self.count = 0    # guarded-by: _lock
                self.min = 0.0    # guarded-by: _lock

            def observe(self, v):
                with self._lock:
                    self.count += 1
                    self.min = min(self.min, v)

            def summary(self):
                with self._lock:
                    count = self.count
                return {"count": count, "min": self.min}
    """})
    hits = active(run_passes(proj, select=["lock-discipline"]))
    assert len(hits) == 1
    assert hits[0].line == 17
    assert "Hist.min" in hits[0].message


def test_lock_discipline_catches_lock_order_cycle(tmp_path):
    proj = make_project(tmp_path, {"src/repro/core/pair.py": """\
        import threading

        class A:
            def __init__(self, b):
                self._lock = threading.Lock()
                self.b: "B" = b

            def poke(self):
                with self._lock:
                    self.b.touch()

            def touch(self):
                with self._lock:
                    pass

        class B:
            def __init__(self, a):
                self._lock = threading.Lock()
                self.a: "A" = a

            def poke(self):
                with self._lock:
                    self.a.touch()

            def touch(self):
                with self._lock:
                    pass
    """})
    hits = active(run_passes(proj, select=["lock-discipline"]))
    assert len(hits) == 1
    assert "lock-order cycle" in hits[0].message
    assert "A._lock" in hits[0].message and "B._lock" in hits[0].message


def test_obs_naming_catches_undocumented_and_dead_names(tmp_path):
    proj = make_project(tmp_path, {
        "src/repro/obs/README.md": OBS_README + "| `obs.dead_row` | unused |\n",
        "src/repro/obs/emit.py": """\
            from repro.obs.metrics import REGISTRY
            from repro.obs.trace import TRACER

            def go():
                TRACER.span("run.clip")
                REGISTRY.counter("executor.dispatches").inc()
                REGISTRY.counter("executor.typo_dispatches").inc()
        """,
    })
    hits = active(run_passes(proj, select=["obs-naming"]))
    assert len(hits) == 2
    undoc = [f for f in hits if f.path == "src/repro/obs/emit.py"]
    dead = [f for f in hits if f.path == "src/repro/obs/README.md"]
    assert len(undoc) == 1 and undoc[0].line == 7
    assert "executor.typo_dispatches" in undoc[0].message
    assert len(dead) == 1 and "obs.dead_row" in dead[0].message


def test_obs_naming_pools_endpoint_health_alert_sections(tmp_path):
    """Endpoint/health/alert rows live in their own heading-scoped
    pools: an undocumented @route path and a dead endpoint row are
    caught, while documented HealthComponent/AlertRule names (and the
    heading-less span/metric tables above them) stay clean."""
    readme = OBS_README + """
        ## Endpoint naming scheme

        | endpoint | payload |
        | --- | --- |
        | `/metrics` | exposition |
        | `/dead_route` | never mounted |

        ## Health-component naming scheme

        | component | watches |
        | --- | --- |
        | `decode_pool` | queue depth |

        ## Alert-rule naming scheme

        | rule | objective |
        | --- | --- |
        | `append_latency` | p95 |
    """
    proj = make_project(tmp_path, {
        "src/repro/obs/README.md": readme,
        "src/repro/obs/emit.py": """\
            from repro.obs.metrics import REGISTRY
            from repro.obs.trace import TRACER

            def go():
                TRACER.span("run.clip")
                REGISTRY.counter("executor.dispatches").inc()
        """,
        "src/repro/obs/plane.py": """\
            def route(path):
                def deco(fn):
                    return fn
                return deco

            @route("/metrics")
            def metrics(server):
                return 200

            @route("/typo_route")
            def typo(server):
                return 200

            class HealthComponent:
                def __init__(self, name, metric):
                    pass

            class AlertRule:
                def __init__(self, name, metric):
                    pass

            COMPONENTS = [HealthComponent(
                "decode_pool", "executor.decode.queue_depth")]
            RULES = [AlertRule(
                "append_latency", "stream.append.wall_seconds")]
        """,
    })
    hits = active(run_passes(proj, select=["obs-naming"]))
    assert len(hits) == 2
    undoc = [f for f in hits if f.path == "src/repro/obs/plane.py"]
    dead = [f for f in hits if f.path == "src/repro/obs/README.md"]
    assert len(undoc) == 1 and "/typo_route" in undoc[0].message
    assert len(dead) == 1 and "/dead_route" in dead[0].message


def test_tracked_bytecode_catches_pyc(tmp_path):
    proj = make_project(tmp_path, {
        "src/repro/core/util.py": "x = 1\n",
    })
    pyc = tmp_path / "src/repro/core/__pycache__/util.cpython-311.pyc"
    pyc.parent.mkdir(parents=True)
    pyc.write_bytes(b"\x00")
    hits = active(run_passes(proj, select=["tracked-bytecode"]))
    assert len(hits) == 1
    assert hits[0].path.endswith("util.cpython-311.pyc")


# -- suppression mechanics ----------------------------------------------------


def test_trailing_suppression_with_why_is_honored(tmp_path):
    proj = make_project(tmp_path, {
        "src/repro/core/tracker.py": """\
            import jax.numpy as jnp

            def gru(x):
                return jnp.tanh(x)  # repro-lint: disable=bit-contract -- train-only head
        """,
    })
    rep = run_passes(proj, select=["bit-contract"])
    assert active(rep) == []
    assert len(rep.suppressed) == 1
    assert rep.suppressed[0].justification == "train-only head"


def test_comment_above_suppresses_next_line_only(tmp_path):
    proj = make_project(tmp_path, {
        "src/repro/core/tracker.py": """\
            import jax.numpy as jnp

            def gru(x):
                # repro-lint: disable=bit-contract -- twin below
                y = jnp.tanh(x)
                return jnp.tanh(y)
        """,
    })
    rep = run_passes(proj, select=["bit-contract"])
    hits = active(rep, "bit-contract")
    assert [f.line for f in hits] == [6]
    assert [f.line for f in rep.suppressed] == [5]


def test_bare_suppression_is_itself_flagged(tmp_path):
    proj = make_project(tmp_path, {
        "src/repro/core/tracker.py": """\
            import jax.numpy as jnp

            def gru(x):
                return jnp.tanh(x)  # repro-lint: disable=bit-contract
        """,
    })
    rep = run_passes(proj, select=["bit-contract"])
    bare = active(rep, "suppression")
    assert len(bare) == 1 and bare[0].line == 4
    assert "justification" in bare[0].message


def test_file_wide_suppression(tmp_path):
    proj = make_project(tmp_path, {
        "src/repro/core/tracker.py": """\
            # repro-lint: disable-file=bit-contract -- fixture: whole file exempt
            import jax.numpy as jnp

            def gru(x):
                return jnp.tanh(x)
        """,
    })
    rep = run_passes(proj, select=["bit-contract"])
    assert active(rep, "bit-contract") == []


def test_unparseable_file_is_a_finding(tmp_path):
    proj = make_project(tmp_path, {
        "src/repro/core/broken.py": "def f(:\n",
    })
    hits = active(run_passes(proj, select=["bit-contract"]), "parse")
    assert len(hits) == 1
    assert "syntax error" in hits[0].message


def test_unknown_pass_id_rejected(tmp_path):
    proj = make_project(tmp_path, {"src/repro/x.py": "x = 1\n"})
    with pytest.raises(KeyError):
        run_passes(proj, select=["no-such-pass"])


# -- clean fixture + the real tree --------------------------------------------


def test_clean_fixture_tree_is_clean(tmp_path):
    proj = make_project(tmp_path, {
        "src/repro/obs/README.md": OBS_README,
        "src/repro/core/tracker.py": """\
            from repro.core.fastmath import np_tanh

            def gru(x):
                return np_tanh(x)
        """,
        "src/repro/kernels/foo/__init__.py": "",
        "src/repro/kernels/foo/kernel.py": """\
            def foo_pallas(x, y, *, block, interpret=False):
                return x
        """,
        "src/repro/kernels/foo/ops.py": "def foo(x, y): return x\n",
        "src/repro/kernels/foo/ref.py": """\
            def foo_ref(x, y, *, block):
                return x
        """,
        "src/repro/kernels/foo/smoke.py": "def smoke(): pass\n",
        "src/repro/obs/box.py": """\
            import threading

            class Box:
                def __init__(self):
                    self._lock = threading.Lock()
                    self._n = 0  # guarded-by: _lock

                def inc(self):
                    with self._lock:
                        self._n += 1

                def peek(self):
                    with self._lock:
                        return self._n
        """,
        "src/repro/obs/emit.py": """\
            from repro.obs.metrics import REGISTRY
            from repro.obs.trace import TRACER

            def go():
                TRACER.span("run.clip")
                REGISTRY.counter("executor.dispatches").inc()
        """,
    })
    rep = run_passes(proj)
    assert active(rep) == [], [str(f) for f in active(rep)]


def test_real_tree_ships_lint_clean():
    proj = Project(REPO_ROOT)
    assert len(proj.files) > 100      # really scanned the tree
    rep = run_passes(proj)
    assert active(rep) == [], [str(f) for f in active(rep)]
    # every suppression in the tree carries a justification
    assert all(f.justification for f in rep.suppressed)


def test_report_json_roundtrip(tmp_path):
    import json
    proj = make_project(tmp_path, {
        "src/repro/core/tracker.py": """\
            import jax.numpy as jnp
            y = jnp.exp(1.0)
        """,
    })
    rep = run_passes(proj, select=["bit-contract"])
    d = json.loads(rep.to_json())
    assert d["counts"]["active"] == 1
    assert d["findings"][0]["pass"] == "bit-contract"
    assert d["findings"][0]["line"] == 2


# -- metrics races the linter caught (functional regression) ------------------


def test_counter_value_and_dispatches_locked_reads():
    om = pytest.importorskip("repro.obs.metrics")
    c = om.Counter()
    c.inc(3)
    assert c.value == 3
    rp = om.RunProfile(["detect"])
    rp.dispatch("detect", 2)
    rp.dispatch("detect")
    assert rp.dispatches("detect") == 3
    assert rp.dispatches("track") == 0


def test_histogram_summary_consistent_snapshot():
    om = pytest.importorskip("repro.obs.metrics")
    h = om.Histogram(window=8)
    for v in (3.0, 1.0, 2.0):
        h.observe(v)
    s = h.summary()
    assert s["count"] == 3 and s["min"] == 1.0 and s["max"] == 3.0
    assert not math.isinf(s["min"])
