"""Per-arch smoke tests (reduced configs, one forward/train step on CPU,
shape + NaN assertions) and the decode-vs-forward consistency invariant."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED_ARCHS, get_config
from repro.models.model import build_model

SMOKE_ARCHS = list(ASSIGNED_ARCHS)


def _batch(cfg, B=2, S=16, key=0):
    k = jax.random.PRNGKey(key)
    batch = {"tokens": jax.random.randint(k, (B, S), 0, cfg.vocab_size)}
    if cfg.family == "encdec":
        batch["audio_embeds"] = jax.random.normal(
            k, (B, cfg.frontend.n_embeds, cfg.d_model)).astype(cfg.dtype)
    if cfg.family == "vlm":
        batch["patch_embeds"] = jax.random.normal(
            k, (B, cfg.frontend.n_embeds, cfg.d_model)).astype(cfg.dtype)
    return batch


@pytest.mark.parametrize("arch", SMOKE_ARCHS)
def test_smoke_forward_and_train_step(arch):
    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    params = model.init_params(0)
    batch = _batch(cfg)
    logits, aux, _ = model.forward(params, batch)
    B, S = batch["tokens"].shape
    assert logits.shape == (B, S, cfg.vocab_size)
    assert not bool(jnp.isnan(logits).any())
    loss, metrics = model.loss(params, batch)
    assert np.isfinite(float(loss))
    # one real optimizer step
    from repro.optim import adamw
    from repro.train import build_train_step
    opt = adamw(lr=1e-3)
    ts = build_train_step(model, opt)
    state = opt.init(params)
    params2, state, mets = jax.jit(lambda p, s, b: ts(p, s, b))(
        params, state, batch)
    assert np.isfinite(float(mets["loss"]))
    # params actually changed
    changed = any(
        float(jnp.abs(a - b).max()) > 0
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(params2)))
    assert changed


# one arch per family keeps the matrix affordable on 1 CPU core; the
# family decode paths are what differ, not the size constants
DECODE_ARCHS = ["qwen2-0.5b", "mamba2-370m", "deepseek-moe-16b",
                "zamba2-7b", "whisper-small", "pixtral-12b"]


@pytest.mark.parametrize("arch", DECODE_ARCHS)
def test_decode_matches_forward_fp32(arch):
    """prefill(S-1) + decode(token S-1) == full forward at position S-1."""
    cfg = dataclasses.replace(get_config(arch).reduced(), dtype="float32")
    if cfg.moe.enabled:
        # ample capacity: capacity drops are train-time-only semantics
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    model = build_model(cfg)
    params = model.init_params(0)
    B, S = 2, 18
    batch = _batch(cfg, B, S)
    logits_full, _, _ = model.forward(params, batch)
    pre = dict(batch)
    pre["tokens"] = batch["tokens"][:, :S - 1]
    _, cache = model.prefill(params, pre, max_len=S + 2)
    lg, _ = model.decode_step(
        params, batch["tokens"][:, S - 1:S],
        jnp.full((B,), S - 1, jnp.int32), cache)
    np.testing.assert_allclose(
        np.asarray(lg), np.asarray(logits_full[:, -1]),
        atol=3e-4, rtol=3e-4)


@pytest.mark.parametrize("arch", SMOKE_ARCHS)
def test_param_count_matches_analytic(arch):
    """configs.base._param_count stays in sync with the real layers."""
    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    actual = model.param_count()
    analytic = cfg.param_count()
    assert abs(actual - analytic) / max(analytic, 1) < 0.03, \
        (arch, actual, analytic)


def test_moe_aux_loss_nonzero():
    cfg = get_config("deepseek-moe-16b").reduced()
    model = build_model(cfg)
    params = model.init_params(0)
    _, aux, _ = model.forward(params, _batch(cfg))
    assert float(aux) > 0.0


def test_full_configs_param_counts():
    """Full (non-reduced) configs match public parameter counts within
    tolerance (analytic count; no allocation)."""
    expected = {
        "deepseek-67b": 67e9, "deepseek-coder-33b": 33e9,
        "qwen2-0.5b": 0.49e9, "stablelm-1.6b": 1.6e9,
        "grok-1-314b": 314e9, "deepseek-moe-16b": 16.4e9,
        "mamba2-370m": 0.37e9, "zamba2-7b": 7.2e9,
        "pixtral-12b": 12e9, "whisper-small": 0.24e9,
    }
    for arch, n in expected.items():
        got = get_config(arch).param_count()
        assert abs(got - n) / n < 0.25, (arch, got, n)
